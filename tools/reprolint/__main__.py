"""CLI: ``python -m tools.reprolint [paths...]``.

Exit status 1 when findings exist (CI gates on it), 2 on usage errors.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .engine import FileContext, Project, _select, iter_py_files, \
    render_json, render_text, run_rules
from .rules import ALL_RULES

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="repo-specific static analysis (see docs/LINTING.md)")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files or directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="stdout format (default: text)")
    ap.add_argument("--json-report", metavar="FILE",
                    help="also write a JSON report to FILE")
    ap.add_argument("--rules", "--only", dest="rules", metavar="ID[,ID...]",
                    help="run only these rules (ids or names, "
                         "comma-separated)")
    ap.add_argument("--disable", metavar="ID[,ID...]",
                    help="skip these rules (ids or names, comma-separated; "
                         "applied after --only)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.id}  {r.name:<24} {r.description}")
        return 0

    def _split(raw):
        return [t.strip() for t in raw.split(",") if t.strip()] \
            if raw else None

    only, disable = _split(args.rules), _split(args.disable)
    try:
        files = list(iter_py_files(args.paths))
        ctxs = [FileContext(str(f), f.read_text()) for f in files]
        picked = _select(ALL_RULES, only, disable)
        findings = run_rules(Project(ctxs), picked)
    except (FileNotFoundError, ValueError) as e:
        print(f"reprolint: error: {e}", file=sys.stderr)
        return 2

    if args.format == "json":
        print(render_json(findings, len(ctxs), picked))
    else:
        print(render_text(findings, len(ctxs)))
    if args.json_report:
        Path(args.json_report).write_text(
            render_json(findings, len(ctxs), picked) + "\n")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
