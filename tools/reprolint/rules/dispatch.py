"""Single-decision-point rules (RPL101/RPL102).

``resolve_engine`` (src/repro/core/engine.py) is the ONLY place allowed
to read the TrainConfig substrate-dispatch fields (``fused_outer``,
``device_outer``, ``mesh_name``), and ``resolve_serve_engine``
(src/repro/serving/engine.py) the only place allowed to read the
ServeConfig dispatch fields (``batching``, ``timing``).  Everyone else
receives a resolved EnginePlan/ServePlan.

These rules replace the raw-source regex checks that used to live in
tests/test_engine.py and tests/test_serve.py: attribute access is
detected on the AST (no false hits inside strings or comments, and
multi-line/aliased receivers still match), and ``getattr(cfg,
"fused_outer")`` — invisible to the regex — is caught too.

A read is attributed to a config object by the RECEIVER name: the last
dotted component of the receiver chain must look like a config binding
(``cfg``, ``tc``, ``self.t.tc``, ``serve_cfg``...).  Constructor
keywords (``TrainConfig(fused_outer=True)``) and reads off clearly
non-config objects (``args.batching``, ``eng.batching``,
``plan.batching``) do not flag — the same receiver discipline the
migrated regex tests enforced.
"""
from __future__ import annotations

import ast

from ..engine import Rule, const_str, terminal_name


class _DispatchFieldRule(Rule):
    fields: frozenset = frozenset()
    receivers: frozenset = frozenset()
    allowed_suffix = ""
    decision_point = ""

    def check(self, ctx, project):
        if ctx.path.endswith(self.allowed_suffix):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute) and node.attr in self.fields:
                if terminal_name(node.value) in self.receivers:
                    yield self.finding(
                        ctx, node,
                        f"reads dispatch field `.{node.attr}` off a config "
                        f"object — only {self.decision_point} may inspect "
                        "it; accept a resolved plan instead")
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Name)
                  and node.func.id == "getattr" and len(node.args) >= 2):
                field = const_str(node.args[1])
                if (field in self.fields
                        and terminal_name(node.args[0]) in self.receivers):
                    yield self.finding(
                        ctx, node,
                        f"getattr-reads dispatch field {field!r} off a "
                        f"config object — only {self.decision_point} may "
                        "inspect it")


class TrainDispatchRule(_DispatchFieldRule):
    """No module but core/engine.py reads the TrainConfig substrate flags."""
    id = "RPL101"
    name = "dispatch-train"
    description = ("fused_outer/device_outer/mesh_name may only be read by "
                   "resolve_engine (src/repro/core/engine.py)")
    fields = frozenset({"fused_outer", "device_outer", "mesh_name"})
    receivers = frozenset({"tc", "cfg", "config", "train_cfg",
                           "train_config"})
    allowed_suffix = "repro/core/engine.py"
    decision_point = "engine.resolve_engine"


class ServeDispatchRule(_DispatchFieldRule):
    """No module but serving/engine.py reads the ServeConfig dispatch
    fields."""
    id = "RPL102"
    name = "dispatch-serve"
    description = ("batching/timing may only be read by "
                   "resolve_serve_engine (src/repro/serving/engine.py)")
    fields = frozenset({"batching", "timing"})
    receivers = frozenset({"sc", "serve", "serve_cfg", "serve_config",
                           "cfg", "config"})
    allowed_suffix = "repro/serving/engine.py"
    decision_point = "serving.engine.resolve_serve_engine"
