"""Rule registry — ALL_RULES is the default rule set for every entry
point (CLI, lint_paths, lint_sources)."""
from __future__ import annotations

from .deprecations import (GreedyGenerateRule, LegacyInitCacheRule,
                           PythonpathRunlineRule)
from .dispatch import ServeDispatchRule, TrainDispatchRule
from .donation import DonatedBufferReuseRule
from .kernels import KernelRoutedRule, KernelVjpRule, SilentFallbackRule
from .shardcheck import (CollectiveAxisRule, Eq7MergeAxisRule,
                         PallasInShardMapRule, PartitionSpecHygieneRule,
                         UnregisteredPytreeRule)
from .trace import HostSyncInTraceRule, NondetInTraceRule

ALL_RULES = [
    TrainDispatchRule(),        # RPL101 dispatch-train
    ServeDispatchRule(),        # RPL102 dispatch-serve
    HostSyncInTraceRule(),      # RPL201 host-sync-in-trace
    NondetInTraceRule(),        # RPL202 nondet-in-trace
    KernelVjpRule(),            # RPL301 kernel-vjp
    SilentFallbackRule(),       # RPL302 silent-fallback
    KernelRoutedRule(),         # RPL303 kernel-unrouted
    GreedyGenerateRule(),       # RPL401 greedy-generate
    LegacyInitCacheRule(),      # RPL402 legacy-init-cache
    PythonpathRunlineRule(),    # RPL403 pythonpath-runline
    DonatedBufferReuseRule(),   # RPL501 donated-buffer-reuse
    CollectiveAxisRule(),       # RPL601 collective-axis-unbound
    Eq7MergeAxisRule(),         # RPL602 eq7-merge-axis
    PartitionSpecHygieneRule(),  # RPL603 partitionspec-hygiene
    UnregisteredPytreeRule(),   # RPL604 unregistered-pytree
    PallasInShardMapRule(),     # RPL605 pallas-in-shardmap
]

__all__ = ["ALL_RULES"]
