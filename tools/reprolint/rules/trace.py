"""Trace-hygiene rules (RPL201/RPL202).

The paper's Eq. 8 feedback loop (IDPA reads measured round durations)
only works if the measured walls are *compute-only*: host syncs inside
traced code silently serialize the device pipeline, and host clocks /
RNGs inside traced code bake a trace-time constant into the compiled
program (the wall-clock-placement bug class fixed in PR 5).

These rules build a per-module trace reachability set — every function
that is jitted / shard_mapped / pallas_called / custom_vjp-registered,
via decorator or call-site wrapping, plus everything those functions
reference transitively inside the module — and flag:

* RPL201 ``host-sync-in-trace``: ``jax.block_until_ready``,
  ``jax.device_get``, ``.item()``, ``np.asarray``/``np.array`` calls.
* RPL202 ``nondet-in-trace``: ``time.*`` calls, stdlib ``random.*`` and
  ``np.random.*`` calls (``jax.random`` is keyed and deterministic and
  does NOT flag), and argless ``datetime.now()``.

``TIMER_ALLOWLIST`` names the engine timer scopes that are *supposed*
to measure walls (the serving ``MeasuredTimer`` — the serving twin of
the PR 7 measured-duration clocks); findings inside those qualnames are
dropped.

Honesty notes: reachability is per-module (a cross-module call into a
host sync is not followed) and name-based (all same-named defs are
treated as one), so the rules are deliberately conservative about what
counts as reachable — suppress with ``# reprolint: disable=RPL201``
where a flagged call is really trace-time-only host bookkeeping.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from ..engine import Rule, terminal_name

# call/decorator names whose function arguments get traced by JAX
TRACE_WRAPPERS = frozenset({
    "jit", "vmap", "pmap", "grad", "value_and_grad", "jacfwd", "jacrev",
    "custom_vjp", "custom_jvp", "defvjp", "defjvp", "checkpoint", "remat",
    "shard_map", "pallas_call", "scan", "while_loop", "fori_loop", "cond",
    "switch", "associative_scan",
})

# innermost enclosing qualnames where wall measurement is the point,
# plus the runtime sanitizer's sanctioned escape hatches (repro.sanitize):
# their bodies ARE the host-sync boundary every other site routes through
TIMER_ALLOWLIST = frozenset({
    "MeasuredTimer.call", "sanctioned_sync", "sanctioned_scope",
})


def _wrapped_fn_names(node: ast.AST) -> Iterator[str]:
    """Function names referenced by an argument passed to a trace
    wrapper: bare ``f``, ``partial(f, ...)``, or nested wrapper calls
    like ``jax.jit(jax.vmap(f))``."""
    if isinstance(node, ast.Name):
        yield node.id
    elif isinstance(node, ast.Call):
        tn = terminal_name(node.func)
        if tn == "partial" and node.args:
            yield from _wrapped_fn_names(node.args[0])
        elif tn in TRACE_WRAPPERS:
            for a in node.args:
                yield from _wrapped_fn_names(a)


class _ModuleTraceIndex:
    """Per-module function defs, aliases, and the traced-reachable set."""

    def __init__(self, tree: ast.AST):
        self.defs: dict[str, list[ast.AST]] = {}
        self.qualname: dict[ast.AST, str] = {}
        self.aliases: dict[str, set[str]] = {}   # var -> referenced fn names
        self._collect(tree, ())
        self.traced: set[ast.AST] = set()
        self._seed_roots(tree)
        self._close_over_references()

    # -- collection ----------------------------------------------------
    def _collect(self, node: ast.AST, stack: tuple):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = ".".join(stack + (child.name,))
                self.defs.setdefault(child.name, []).append(child)
                self.qualname[child] = q
                self._collect(child, stack + (child.name,))
            elif isinstance(child, ast.ClassDef):
                self._collect(child, stack + (child.name,))
            else:
                if isinstance(child, ast.Assign) and len(child.targets) == 1:
                    tgt = child.targets[0]
                    names = set(_wrapped_fn_names(child.value))
                    if isinstance(tgt, ast.Name) and names:
                        self.aliases.setdefault(tgt.id, set()).update(names)
                self._collect(child, stack)

    def _resolve(self, name: str) -> list[ast.AST]:
        out = list(self.defs.get(name, ()))
        for ref in self.aliases.get(name, ()):
            out.extend(self.defs.get(ref, ()))
        return out

    # -- roots: decorators + call-site wrapping ------------------------
    def _seed_roots(self, tree: ast.AST):
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if self._is_trace_wrapper(dec):
                        self.traced.add(node)
            elif isinstance(node, ast.Call):
                if terminal_name(node.func) in TRACE_WRAPPERS:
                    for a in node.args:
                        for fn in _wrapped_fn_names(a):
                            self.traced.update(self._resolve(fn))

    @staticmethod
    def _is_trace_wrapper(dec: ast.AST) -> bool:
        if terminal_name(dec) in TRACE_WRAPPERS:
            return True
        if isinstance(dec, ast.Call):            # @partial(jax.jit, ...)
            tn = terminal_name(dec.func)
            if tn in TRACE_WRAPPERS:
                return True
            if tn == "partial" and dec.args:
                return terminal_name(dec.args[0]) in TRACE_WRAPPERS
        return False

    # -- transitive closure over intra-module references ---------------
    def _close_over_references(self):
        work = list(self.traced)
        while work:
            fn = work.pop()
            for node in ast.walk(fn):
                if isinstance(node, ast.Name):
                    for d in self._resolve(node.id):
                        if d not in self.traced:
                            self.traced.add(d)
                            work.append(d)
                # defs nested in a traced def run at trace time too (e.g.
                # the @pl.when-decorated bodies inside Pallas kernels)
                elif (node is not fn and node in self.qualname
                        and node not in self.traced):
                    self.traced.add(node)
                    work.append(node)


def _np_receiver(node: ast.AST) -> bool:
    return isinstance(node, ast.Name) and node.id in ("np", "numpy", "onp")


def _host_sync_reason(call: ast.Call) -> Optional[str]:
    fn = call.func
    tn = terminal_name(fn)
    if tn in ("block_until_ready", "device_get"):
        return f"`{tn}` forces a host sync"
    if (isinstance(fn, ast.Attribute) and fn.attr == "item"
            and not call.args and not call.keywords):
        return "`.item()` pulls a traced value to host"
    if (isinstance(fn, ast.Attribute) and fn.attr in ("asarray", "array")
            and _np_receiver(fn.value)):
        return (f"`np.{fn.attr}` materializes a traced value on host "
                "(use jnp)")
    return None


def _nondet_reason(call: ast.Call) -> Optional[str]:
    fn = call.func
    if not isinstance(fn, ast.Attribute):
        return None
    base = fn.value
    if isinstance(base, ast.Name) and base.id == "time":
        return (f"`time.{fn.attr}` reads the host clock — a trace-time "
                "constant inside compiled code")
    if isinstance(base, ast.Name) and base.id == "random":
        return (f"stdlib `random.{fn.attr}` is untraced host RNG "
                "(use jax.random with an explicit key)")
    if (isinstance(base, ast.Attribute) and base.attr == "random"
            and _np_receiver(base.value)):
        return (f"`np.random.{fn.attr}` is untraced host RNG "
                "(use jax.random with an explicit key)")
    if (fn.attr == "now" and not call.args and not call.keywords
            and "datetime" in ast.dump(base)):
        return "argless `datetime.now()` is a trace-time constant"
    return None


def _own_body(fn: ast.AST):
    """Descendants of ``fn`` excluding nested function-def subtrees —
    each traced def is scanned exactly once, under its own qualname."""
    todo = list(ast.iter_child_nodes(fn))
    while todo:
        n = todo.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield n
        todo.extend(ast.iter_child_nodes(n))


def _allowlisted(qualname: str) -> bool:
    return any(qualname == a or qualname.startswith(a + ".")
               for a in TIMER_ALLOWLIST)


class _TraceHygieneRule(Rule):
    """Shared machinery: walk traced-reachable functions, flag calls."""

    def _reason(self, call: ast.Call) -> Optional[str]:
        raise NotImplementedError

    def check(self, ctx, project):
        idx = _ModuleTraceIndex(ctx.tree)
        for fn in sorted(idx.traced, key=lambda f: f.lineno):
            q = idx.qualname[fn]
            if _allowlisted(q):
                continue
            for node in _own_body(fn):
                if isinstance(node, ast.Call):
                    reason = self._reason(node)
                    if reason:
                        yield self.finding(
                            ctx, node,
                            f"{reason} inside `{q}`, which is reachable "
                            "from a jit/shard_map/pallas_call/custom_vjp "
                            "scope")


class HostSyncInTraceRule(_TraceHygieneRule):
    """No host syncs inside traced code: they stall the device pipeline
    and make Eq. 8 walls measure host work."""
    id = "RPL201"
    name = "host-sync-in-trace"
    description = ("block_until_ready / device_get / .item() / np.asarray "
                   "must not run inside trace-reachable functions")

    def _reason(self, call):
        return _host_sync_reason(call)


class NondetInTraceRule(_TraceHygieneRule):
    """No host clocks or untraced RNG inside traced code: the value is
    frozen at trace time, so the compiled program silently replays it."""
    id = "RPL202"
    name = "nondet-in-trace"
    description = ("time.* / random.* / np.random.* / argless datetime.now "
                   "must not run inside trace-reachable functions (timer "
                   "scopes in TIMER_ALLOWLIST exempt)")

    def _reason(self, call):
        return _nondet_reason(call)
