"""Donation-safety rule (RPL501).

``jax.jit(..., donate_argnums=...)`` hands the donated argument's device
buffer back to XLA — after the call the old array is logically dead, and
touching it raises (or, on backends without donation, silently aliases).
The engines donate the carried (params, opt_state) every round, so a
reuse bug here corrupts training state.

RPL501 ``donated-buffer-reuse`` tracks, per function suite:

* jitted bindings with a literal ``donate_argnums``
  (``f = jax.jit(step, donate_argnums=(0, 1))`` and the decorator form
  ``@partial(jax.jit, donate_argnums=(0,))``), and
* each call through such a binding whose donated positional argument is
  a bare ``Name``.

A later statement in the same suite that reads the donated name flags —
unless the name was rebound first (assignment, aug-assign, for-target,
with-target).  The idiomatic fix IS the rebind: ``params, opt =
step(params, opt)``.  The analysis is suite-local and name-based on
purpose (no heap model): cross-function flows and attribute receivers
are out of scope, which keeps the rule's false-positive rate near zero.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from ..engine import Rule, terminal_name


def _literal_argnums(call: ast.Call) -> Optional[tuple[int, ...]]:
    """The donate_argnums literal of a jit/pjit call, or None."""
    if terminal_name(call.func) not in ("jit", "pjit"):
        return None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)) and all(
                    isinstance(e, ast.Constant) and isinstance(e.value, int)
                    for e in v.elts):
                return tuple(e.value for e in v.elts)
            return None
    return None


def _find_jit_call(node: ast.AST) -> Optional[ast.Call]:
    """The jit(...) call inside possibly-nested wrapping, e.g.
    ``jax.jit(jax.vmap(f), donate_argnums=(0,))``."""
    if isinstance(node, ast.Call):
        if _literal_argnums(node) is not None:
            return node
        for a in node.args:
            got = _find_jit_call(a)
            if got is not None:
                return got
    return None


class _DonatingBindings(ast.NodeVisitor):
    """Maps names (``self._fused_round``, ``step``) to donated argnums."""

    def __init__(self):
        self.bindings: dict[str, tuple[int, ...]] = {}

    def visit_Assign(self, node: ast.Assign):
        jit = _find_jit_call(node.value)
        if jit is not None and len(node.targets) == 1:
            tn = terminal_name(node.targets[0])
            if tn:
                self.bindings[tn] = _literal_argnums(jit)
        self.generic_visit(node)

    def _visit_def(self, node):
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call):
                nums = _literal_argnums(dec)
                if nums is None and terminal_name(dec.func) == "partial" \
                        and dec.args:
                    inner = ast.Call(func=dec.args[0], args=[],
                                     keywords=dec.keywords)
                    nums = _literal_argnums(inner)
                if nums is not None:
                    self.bindings[node.name] = nums
        self.generic_visit(node)

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def


def _bound_names(target: ast.AST) -> Iterator[str]:
    for n in ast.walk(target):
        if isinstance(n, ast.Name):
            yield n.id


def _own_walk(stmt: ast.AST):
    """``stmt``'s subtree excluding nested scopes (function/class/lambda
    bodies) — each nested function body is dataflow-scanned as its own
    suite, so donations and reads must not leak across scopes."""
    yield stmt
    todo = list(ast.iter_child_nodes(stmt))
    while todo:
        n = todo.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef, ast.Lambda)):
            continue
        yield n
        todo.extend(ast.iter_child_nodes(n))


def _stmt_rebinds(stmt: ast.stmt) -> set[str]:
    """Names (re)bound anywhere in the statement's own scope — for a
    compound statement (for/if/with) that includes bindings in its
    nested suites, so a loop-body ``params, opt = run(params, opt)``
    counts as rebinding at the enclosing-suite granularity."""
    out: set[str] = set()
    for n in _own_walk(stmt):
        if isinstance(n, ast.Assign):
            for t in n.targets:
                out.update(_bound_names(t))
        elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
            out.update(_bound_names(n.target))
        elif isinstance(n, (ast.For, ast.AsyncFor)):
            out.update(_bound_names(n.target))
        elif isinstance(n, (ast.With, ast.AsyncWith)):
            for item in n.items:
                if item.optional_vars is not None:
                    out.update(_bound_names(item.optional_vars))
    return out


def _stmt_reads(stmt: ast.stmt) -> dict[str, ast.Name]:
    """First Load-context Name node per id in the statement's own scope."""
    out: dict[str, ast.Name] = {}
    for n in _own_walk(stmt):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                and n.id not in out:
            out[n.id] = n
    return out


class DonatedBufferReuseRule(Rule):
    """A donated argument must not be read again after the donating call
    in the same suite (rebind it from the call's result instead)."""
    id = "RPL501"
    name = "donated-buffer-reuse"
    description = ("an argument donated via donate_argnums is dead after "
                   "the call — rebind it from the result before reuse")

    def check(self, ctx, project):
        binder = _DonatingBindings()
        binder.visit(ctx.tree)
        if not binder.bindings:
            return
        for node in ast.walk(ctx.tree):
            body = getattr(node, "body", None)
            if isinstance(body, list):
                yield from self._scan_suite(ctx, binder.bindings, body)
            for attr in ("orelse", "finalbody"):
                suite = getattr(node, attr, None)
                if isinstance(suite, list) and suite:
                    yield from self._scan_suite(ctx, binder.bindings, suite)

    def _scan_suite(self, ctx, bindings, suite):
        if not all(isinstance(s, ast.stmt) for s in suite):
            return
        # donated-name -> the call statement's lineno, for the message
        dead: dict[str, int] = {}
        for stmt in suite:
            # a def/class statement opens its own scope — its body is
            # scanned as its own suite; here it only rebinds its name
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                dead.pop(stmt.name, None)
                continue
            # reads of currently-dead names flag before this statement's
            # own rebinds resurrect them (`x = f(x)` after donating x is
            # itself a reuse of the dead x)
            for name, node in _stmt_reads(stmt).items():
                if name in dead and not self._is_donating_call_arg(
                        stmt, bindings, name):
                    yield self.finding(
                        ctx, node,
                        f"`{name}` was donated to a jitted call on line "
                        f"{dead[name]} (donate_argnums) — its buffer is "
                        "dead; rebind it from the call's result before "
                        "reusing it")
                    del dead[name]      # one finding per donation
            for call in _own_walk(stmt):
                if not isinstance(call, ast.Call):
                    continue
                nums = bindings.get(terminal_name(call.func) or "")
                if not nums:
                    continue
                for i in nums:
                    if i < len(call.args) and \
                            isinstance(call.args[i], ast.Name):
                        dead[call.args[i].id] = call.lineno
            # a name rebound within the donating statement itself holds
            # the call's RESULT, not the donated buffer — the idiomatic
            # `params, opt = step(params, opt)` (bare or inside a loop
            # suite) stays clean:
            for name in _stmt_rebinds(stmt):
                dead.pop(name, None)

    @staticmethod
    def _is_donating_call_arg(stmt, bindings, name) -> bool:
        """True if every read of ``name`` in this statement is as an
        argument of a donating call — that read is the donation itself,
        not a reuse."""
        for call in _own_walk(stmt):
            if isinstance(call, ast.Call) and \
                    bindings.get(terminal_name(call.func) or ""):
                for a in call.args:
                    if isinstance(a, ast.Name) and a.id == name:
                        return True
        return False
