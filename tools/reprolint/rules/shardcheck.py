"""shardcheck: mesh/collective static analysis (RPL601-RPL605).

The paper's bi-layered architecture lives or dies on axis discipline:
the outer BPT layer all-reduces over ``nodes`` (Eq. 7) while the inner
per-layer plans collectivize over ``model`` — a collective issued over
the wrong axis name either crashes at dispatch (unbound name) or, far
worse, silently merges the wrong groups (bound-but-wrong name on a 2-D
hybrid mesh).  These rules machine-check the axis contracts the
equivalence suite can only spot-check dynamically:

* RPL601 ``collective-axis-unbound``: every ``lax.psum`` /
  ``psum_scatter`` / ``all_gather`` / ``axis_index`` / ... axis name
  must be bound by the enclosing ``shard_map`` mesh.  Mesh axes resolve
  cross-file through ``launch/mesh.py``: the ``MESHES`` registry (named
  meshes), the factory signatures (``make_nodes_mesh`` -> ``nodes``,
  ``make_hybrid_mesh`` -> ``nodes``/``model``, ``make_production_mesh``
  -> ``pod``/``data``/``model``), and the union of all axis tuples as
  the repo-wide vocabulary fallback when the local mesh expression is
  not statically resolvable.
* RPL602 ``eq7-merge-axis``: inside the Eq. 7 merge scope (``core/
  gwu.py``, or any function whose name mentions ``gwu``) reduction
  collectives must run over ``nodes`` ONLY — a ``psum(..., "model")``
  there would average the per-node replicas *within* one node's model
  shards and silently break Eq. 7's cross-node weighted merge.
* RPL603 ``partitionspec-hygiene``: ``PartitionSpec`` literals whose
  axis names are not in the mesh vocabulary flag everywhere; specs with
  literal axes that are NOT attached to a mesh-consuming op
  (``NamedSharding`` / ``shard_map`` / ``with_sharding_constraint`` /
  ``device_put``), directly or via a local name, must live in the spec
  owner modules (``core/planner.py``, ``launch/sharding.py``) — orphan
  specs elsewhere drift from the planner's layout decisions.
* RPL604 ``unregistered-pytree``: a module-local dataclass constructed
  inside trace-reachable code (the RPL201 reachability machinery, which
  seeds from jit/shard_map/pallas_call/checkpoint wrapping) must be
  registered with the pytree registry, else jax treats the instance as
  a static leaf (hash by id -> silent retrace per instance) or rejects
  it outright.
* RPL605 ``pallas-in-shardmap``: a ``shard_map`` whose body reaches a
  ``pallas_call`` (inline or via an intra-module def) must pass an
  explicit ``check_rep=False`` — the shard_map replication checker has
  no rule for Pallas kernels and rejects the round at trace time; the
  explicit keyword documents that the equivalence suite gates the
  semantics instead.

Honesty notes (mirroring the RPL201 contract): reachability and name
resolution are per-module and name-based; axis names that are not
statically resolvable (function parameters without defaults, attribute
reads like ``plan.axis``) are skipped, not guessed.  Suppress with
``# reprolint: disable=RPL60x`` where a flagged site is deliberate,
and say why on the line.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional

from ..engine import FileContext, Project, Rule, const_str, terminal_name
from .trace import _ModuleTraceIndex, _own_body, _wrapped_fn_names

MESH_MODULE = "launch/mesh.py"

# fallback vocabulary when no mesh module is in reach (fixture projects)
DEFAULT_AXES = frozenset({"nodes", "model", "data", "pod"})

COLLECTIVES = frozenset({
    "psum", "pmean", "pmax", "pmin", "psum_scatter", "all_gather",
    "all_to_all", "ppermute", "pshuffle", "pbroadcast", "axis_index",
})
REDUCTIONS = COLLECTIVES - {"axis_index"}

# positional index of the axis-name argument (default 1: psum(x, axis))
_AXIS_POS = {"axis_index": 0}

# mesh factories in launch/mesh.py and the axes their meshes carry
MESH_FACTORY_AXES = {
    "make_nodes_mesh": frozenset({"nodes"}),
    "make_hybrid_mesh": frozenset({"nodes", "model"}),
    "make_production_mesh": frozenset({"pod", "data", "model"}),
}

# modules allowed to own orphan PartitionSpecs (RPL603)
SPEC_OWNERS = ("core/planner.py", "launch/sharding.py")

# calls that "ship" a spec with a mesh — a spec inside one is attached
SHIPPING_CALLS = frozenset({
    "NamedSharding", "shard_map", "with_sharding_constraint", "device_put",
})

# pytree registration entry points (RPL604)
REGISTER_CALLS = frozenset({
    "register_dataclass", "register_pytree_node",
    "register_pytree_node_class", "register_static",
    "register_pytree_with_keys", "register_pytree_with_keys_class",
})


# ----------------------------------------------------------------------
# mesh-axis resolution (shared by RPL601/602/603)
# ----------------------------------------------------------------------
def _string_tuple(node: ast.AST) -> Optional[tuple]:
    """A tuple literal whose elements are all string constants — the
    shape every mesh axis tuple in launch/mesh.py takes."""
    if (isinstance(node, ast.Tuple) and node.elts
            and all(isinstance(e, ast.Constant) and isinstance(e.value, str)
                    for e in node.elts)):
        return tuple(e.value for e in node.elts)
    return None


def _mesh_registry(project: Project):
    """(vocabulary, {mesh_name: axes}) resolved from ``launch/mesh.py``.

    The vocabulary is the union of every axis tuple in the mesh module
    (``MESHES`` values, factory literals, ``data_axes`` filters); named
    meshes come from the ``MESHES = {...}`` dict literal.  Falls back to
    ``DEFAULT_AXES`` when the module is out of reach.  Cached on the
    project.
    """
    cached = getattr(project, "_shardcheck_meshes", None)
    if cached is not None:
        return cached
    vocab: set = set()
    named: dict = {}
    ctx = project.find(MESH_MODULE)
    if ctx is not None and ctx.tree is not None:
        for node in ast.walk(ctx.tree):
            axes = _string_tuple(node)
            if axes:
                vocab.update(axes)
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == "MESHES"
                    and isinstance(node.value, ast.Dict)):
                for k, v in zip(node.value.keys, node.value.values):
                    name = const_str(k) if k is not None else None
                    if (name and isinstance(v, ast.Tuple)
                            and len(v.elts) == 2):
                        axes = _string_tuple(v.elts[1])
                        if axes:
                            named[name] = frozenset(axes)
    if not vocab:
        vocab = set(DEFAULT_AXES)
    out = (frozenset(vocab), named)
    project._shardcheck_meshes = out
    return out


def _assign_map(tree: ast.AST) -> dict:
    """name -> RHS nodes of every single-target assignment (module or
    function scope; same-named bindings merge, and a name with more than
    one binding resolves to nothing — conservative)."""
    out: dict = {}
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            out.setdefault(node.targets[0].id, []).append(node.value)
    return out


def _mesh_axes_of(expr: Optional[ast.AST], assigns: dict,
                  named: dict) -> Optional[frozenset]:
    """Static axes of a mesh expression, or None when unresolvable
    (factory call, ``make_mesh("name")``, ``Mesh(devs, ("a","b"))``, or
    a name with a unique local binding to one of those)."""
    if isinstance(expr, ast.Call):
        tn = terminal_name(expr.func)
        if tn in MESH_FACTORY_AXES:
            return MESH_FACTORY_AXES[tn]
        if tn == "make_mesh" and expr.args:
            name = const_str(expr.args[0])
            if name in named:
                return named[name]
        if tn == "Mesh":
            for a in list(expr.args) + [kw.value for kw in expr.keywords]:
                axes = _string_tuple(a)
                if axes:
                    return frozenset(axes)
    elif isinstance(expr, ast.Name):
        rhs = assigns.get(expr.id)
        if rhs is not None and len(rhs) == 1 \
                and not isinstance(rhs[0], ast.Name):
            return _mesh_axes_of(rhs[0], {}, named)
    return None


def _enclosing_map(tree: ast.AST) -> dict:
    """node -> innermost enclosing FunctionDef (None at module level)."""
    enc: dict = {}

    def visit(node, cur):
        for child in ast.iter_child_nodes(node):
            enc[child] = cur
            nxt = child if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef)) else cur
            visit(child, nxt)

    visit(tree, None)
    return enc


def _param_default(fn: ast.AST, name: str) -> Optional[ast.AST]:
    """The default expression for parameter ``name`` of ``fn``."""
    a = fn.args
    pos = list(a.posonlyargs) + list(a.args)
    # defaults tail-align with the positional parameters
    for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        if p.arg == name:
            return d
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if p.arg == name and d is not None:
            return d
    return None


def _axis_names(expr: ast.AST, fn: Optional[ast.AST],
                assigns: dict) -> list:
    """Statically resolvable axis-name strings in a collective's axis
    argument; [] when unresolvable (parameters without defaults,
    ``plan.axis`` attribute reads — conservative skip, not a guess)."""
    s = const_str(expr)
    if s is not None:
        return [s]
    if isinstance(expr, ast.Tuple):
        out = []
        for e in expr.elts:
            out.extend(_axis_names(e, fn, assigns))
        return out
    if isinstance(expr, ast.Name):
        if fn is not None:
            d = _param_default(fn, expr.id)
            if d is not None:
                return _axis_names(d, None, assigns)
        rhs = assigns.get(expr.id)
        if rhs is not None and len(rhs) == 1:
            s = const_str(rhs[0])
            if s is not None:
                return [s]
    return []


def _collective_name(call: ast.Call) -> Optional[str]:
    """The collective's name when ``call`` is a bare or lax-qualified
    collective (``psum(...)``, ``lax.psum``, ``jax.lax.psum``) — method
    calls like ``self.psum`` do not count."""
    tn = terminal_name(call.func)
    if tn not in COLLECTIVES:
        return None
    f = call.func
    if isinstance(f, ast.Name):
        return tn
    if isinstance(f, ast.Attribute) and terminal_name(f.value) == "lax":
        return tn
    return None


def _axis_arg(call: ast.Call, cname: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == "axis_name":
            return kw.value
    pos = _AXIS_POS.get(cname, 1)
    return call.args[pos] if len(call.args) > pos else None


class _ShardMapScopes:
    """Per-module map: function def -> axes of the shard_map mesh whose
    body reaches it (None = reached by a shard_map whose mesh is not
    statically resolvable — treat as the global vocabulary).

    Reachability mirrors ``_ModuleTraceIndex``: seed from the wrapped
    function argument, close over intra-module name references and
    nested defs.  A def reached by several shard_maps is allowed the
    union of their axes.
    """

    def __init__(self, ctx: FileContext, named: dict):
        idx = _ModuleTraceIndex(ctx.tree)
        assigns = _assign_map(ctx.tree)
        self.fn_axes: dict = {}
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and terminal_name(node.func) == "shard_map"):
                continue
            mesh_expr = None
            for kw in node.keywords:
                if kw.arg == "mesh":
                    mesh_expr = kw.value
            if mesh_expr is None and len(node.args) >= 2:
                mesh_expr = node.args[1]
            axes = _mesh_axes_of(mesh_expr, assigns, named)
            reach: set = set()
            if node.args:
                for fname in _wrapped_fn_names(node.args[0]):
                    reach.update(idx._resolve(fname))
            work = list(reach)
            while work:
                fn = work.pop()
                for n in ast.walk(fn):
                    if isinstance(n, ast.Name):
                        for d in idx._resolve(n.id):
                            if d not in reach:
                                reach.add(d)
                                work.append(d)
                    elif (n is not fn and n in idx.qualname
                            and n not in reach):
                        reach.add(n)
                        work.append(n)
            for d in reach:
                if d not in self.fn_axes:
                    self.fn_axes[d] = axes
                elif self.fn_axes[d] is None or axes is None:
                    self.fn_axes[d] = None
                else:
                    self.fn_axes[d] = frozenset(self.fn_axes[d] | axes)


def _binding_axes(fn: Optional[ast.AST], scopes: _ShardMapScopes,
                  enc: dict, vocab: frozenset):
    """(allowed_axes, bound) for a call site: the innermost enclosing
    def a shard_map reaches decides; otherwise the global vocabulary
    (bound=False -> the site is outside any resolvable shard_map)."""
    d = fn
    while d is not None:
        if d in scopes.fn_axes:
            axes = scopes.fn_axes[d]
            return (vocab, False) if axes is None else (axes, True)
        d = enc.get(d)
    return vocab, False


# ----------------------------------------------------------------------
# RPL601
# ----------------------------------------------------------------------
class CollectiveAxisRule(Rule):
    """Collective axis names must be bound by the enclosing shard_map
    mesh (resolved through launch/mesh.py), or at minimum exist in the
    repo's mesh-axis vocabulary."""
    id = "RPL601"
    name = "collective-axis-unbound"
    description = ("lax collective axis names must be bound by the "
                   "enclosing shard_map mesh (launch/mesh.py vocabulary)")

    def check(self, ctx: FileContext,
              project: Project) -> Iterator:
        vocab, named = _mesh_registry(project)
        scopes = _ShardMapScopes(ctx, named)
        enc = _enclosing_map(ctx.tree)
        assigns = _assign_map(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            cname = _collective_name(node)
            if cname is None:
                continue
            arg = _axis_arg(node, cname)
            if arg is None:
                continue
            fn = enc.get(node)
            allowed, bound = _binding_axes(fn, scopes, enc, vocab)
            for nm in _axis_names(arg, fn, assigns):
                if nm not in allowed:
                    where = (f"the enclosing shard_map mesh "
                             f"(axes {sorted(allowed)})" if bound else
                             f"any repo mesh (vocabulary {sorted(vocab)})")
                    yield self.finding(
                        ctx, node,
                        f"`{cname}` over axis '{nm}' is not bound by "
                        f"{where}")


# ----------------------------------------------------------------------
# RPL602
# ----------------------------------------------------------------------
class Eq7MergeAxisRule(Rule):
    """The Eq. 7 merge reduces over ``nodes`` only: a reduction
    collective over any other axis inside the GWU merge scope silently
    merges the wrong groups on a hybrid mesh."""
    id = "RPL602"
    name = "eq7-merge-axis"
    description = ("reduction collectives in the Eq. 7 merge scope "
                   "(core/gwu.py, *gwu* functions) must psum over "
                   "'nodes', never 'model'")

    def check(self, ctx: FileContext,
              project: Project) -> Iterator:
        in_gwu_module = ctx.path.endswith("core/gwu.py")
        enc = _enclosing_map(ctx.tree)
        assigns = _assign_map(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            cname = _collective_name(node)
            if cname is None or cname not in REDUCTIONS:
                continue
            fn = enc.get(node)
            scoped = in_gwu_module
            d = fn
            while d is not None and not scoped:
                scoped = "gwu" in d.name.lower()
                d = enc.get(d)
            if not scoped:
                continue
            arg = _axis_arg(node, cname)
            if arg is None:
                continue
            for nm in _axis_names(arg, fn, assigns):
                if nm != "nodes":
                    yield self.finding(
                        ctx, node,
                        f"Eq. 7 merge `{cname}` reduces over '{nm}' — "
                        "the weighted merge is a cross-node collective "
                        "and must reduce over 'nodes' only")


# ----------------------------------------------------------------------
# RPL603
# ----------------------------------------------------------------------
def _is_test_path(path: str) -> bool:
    parts = path.split("/")
    base = parts[-1]
    return ("tests" in parts or base.startswith("test_")
            or base == "conftest.py")


class PartitionSpecHygieneRule(Rule):
    """PartitionSpec literal axes must exist in the mesh vocabulary, and
    orphan specs (not attached to a mesh-consuming op) belong to the
    spec owner modules."""
    id = "RPL603"
    name = "partitionspec-hygiene"
    description = ("PartitionSpec axes must be mesh-vocabulary names; "
                   "orphan literal specs only in core/planner.py / "
                   "launch/sharding.py")

    def check(self, ctx: FileContext,
              project: Project) -> Iterator:
        vocab, named = _mesh_registry(project)
        tree = ctx.tree
        # names bound to the PartitionSpec constructor in this module
        aliases = {"PartitionSpec"}
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom):
                for a in node.names:
                    if a.name == "PartitionSpec":
                        aliases.add(a.asname or a.name)
            elif (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and terminal_name(node.value) == "PartitionSpec"):
                aliases.add(node.targets[0].id)

        def is_spec_call(n):
            return (isinstance(n, ast.Call)
                    and terminal_name(n.func) in aliases)

        # specs shipped with a mesh: inside a shipping call's subtree,
        # or assigned to a name that a shipping call references
        shipped: set = set()
        shipped_names: set = set()
        shard_axes: dict = {}    # spec call -> resolvable shard_map axes
        assigns = _assign_map(tree)
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and terminal_name(node.func) in SHIPPING_CALLS):
                continue
            axes = None
            if terminal_name(node.func) == "shard_map":
                mesh_expr = None
                for kw in node.keywords:
                    if kw.arg == "mesh":
                        mesh_expr = kw.value
                if mesh_expr is None and len(node.args) >= 2:
                    mesh_expr = node.args[1]
                axes = _mesh_axes_of(mesh_expr, assigns, named)
            for sub in ast.walk(node):
                if sub is not node and is_spec_call(sub):
                    shipped.add(sub)
                    if axes is not None:
                        shard_axes[sub] = axes
                elif isinstance(sub, ast.Name):
                    shipped_names.add(sub.id)
        for node in ast.walk(tree):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id in shipped_names
                    and is_spec_call(node.value)):
                shipped.add(node.value)

        owner = any(ctx.path.endswith(o) for o in SPEC_OWNERS)
        for node in ast.walk(tree):
            if not is_spec_call(node):
                continue
            literals = []
            for a in node.args:
                s = const_str(a)
                if s is not None:
                    literals.append(s)
                else:
                    t = _string_tuple(a)
                    if t:
                        literals.extend(t)
            if not literals:        # P(), P(*dyn), P(None, ...) — nothing
                continue            # statically checkable
            allowed = shard_axes.get(node, vocab)
            for nm in literals:
                if nm not in allowed:
                    yield self.finding(
                        ctx, node,
                        f"PartitionSpec axis '{nm}' is not in the mesh "
                        f"axes {sorted(allowed)}")
            # orphan ownership: fixtures in tests/ construct specs on
            # purpose, so only axis validation applies there
            if (not owner and not _is_test_path(ctx.path)
                    and node not in shipped):
                yield self.finding(
                    ctx, node,
                    "literal PartitionSpec not attached to any mesh-"
                    "consuming op (NamedSharding/shard_map/"
                    "with_sharding_constraint/device_put) — orphan "
                    "specs belong in core/planner.py or "
                    "launch/sharding.py")


# ----------------------------------------------------------------------
# RPL604
# ----------------------------------------------------------------------
def _dataclass_defs(tree: ast.AST) -> dict:
    """name -> ClassDef for module-local @dataclass classes."""
    out: dict = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if terminal_name(target) == "dataclass":
                out[node.name] = node
    return out


def _registered_names(tree: ast.AST) -> set:
    """Class names registered with the pytree registry in this module
    (register_* call arguments or class decorators)."""
    out: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) \
                and terminal_name(node.func) in REGISTER_CALLS:
            for a in node.args:
                if isinstance(a, ast.Name):
                    out.add(a.id)
        elif isinstance(node, ast.ClassDef):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if terminal_name(target) in REGISTER_CALLS:
                    out.add(node.name)
    return out


class UnregisteredPytreeRule(Rule):
    """Dataclasses crossing a jit/shard_map/checkpoint boundary must be
    pytree-registered, else jax hashes the instance as a static leaf
    (silent per-instance retrace) or rejects it."""
    id = "RPL604"
    name = "unregistered-pytree"
    description = ("module-local dataclasses constructed in trace-"
                   "reachable code must be pytree-registered "
                   "(register_dataclass & friends)")

    def check(self, ctx: FileContext,
              project: Project) -> Iterator:
        dcs = _dataclass_defs(ctx.tree)
        if not dcs:
            return
        unregistered = set(dcs) - _registered_names(ctx.tree)
        if not unregistered:
            return
        idx = _ModuleTraceIndex(ctx.tree)
        for fn in sorted(idx.traced, key=lambda f: f.lineno):
            q = idx.qualname[fn]
            for node in _own_body(fn):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Name)
                        and node.func.id in unregistered):
                    yield self.finding(
                        ctx, node,
                        f"dataclass `{node.func.id}` is constructed "
                        f"inside `{q}` (trace-reachable) but never "
                        "pytree-registered — register it with "
                        "jax.tree_util.register_dataclass")


# ----------------------------------------------------------------------
# RPL605
# ----------------------------------------------------------------------
class PallasInShardMapRule(Rule):
    """shard_map over a Pallas kernel needs explicit check_rep=False:
    the replication checker has no rule for pallas_call and rejects the
    program at trace time."""
    id = "RPL605"
    name = "pallas-in-shardmap"
    description = ("shard_map bodies reaching pallas_call must pass "
                   "check_rep=False explicitly")

    @staticmethod
    def _has_pallas(node: ast.AST) -> bool:
        return any(isinstance(n, ast.Call)
                   and terminal_name(n.func) == "pallas_call"
                   for n in ast.walk(node))

    def check(self, ctx: FileContext,
              project: Project) -> Iterator:
        idx = _ModuleTraceIndex(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and terminal_name(node.func) == "shard_map"
                    and node.args):
                continue
            # inline bodies plus intra-module defs the body reaches
            pallas = self._has_pallas(node.args[0])
            if not pallas:
                reach: set = set()
                for fname in _wrapped_fn_names(node.args[0]):
                    reach.update(idx._resolve(fname))
                work = list(reach)
                while work and not pallas:
                    fn = work.pop()
                    if self._has_pallas(fn):
                        pallas = True
                        break
                    for n in ast.walk(fn):
                        if isinstance(n, ast.Name):
                            for d in idx._resolve(n.id):
                                if d not in reach:
                                    reach.add(d)
                                    work.append(d)
            if not pallas:
                continue
            check_rep = None
            for kw in node.keywords:
                if kw.arg == "check_rep":
                    check_rep = kw.value
            ok = (isinstance(check_rep, ast.Constant)
                  and check_rep.value is False)
            if not ok:
                yield self.finding(
                    ctx, node,
                    "shard_map body reaches a pallas_call but does not "
                    "pass check_rep=False — the replication checker "
                    "rejects Pallas kernels at trace time")
