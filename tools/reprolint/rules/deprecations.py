"""Deprecation-ban rules (RPL401/RPL402/RPL403).

Deprecated surfaces stay importable for one release with a warning shim;
these rules stop NEW call sites from creeping in while the shim exists:

* RPL401 ``greedy-generate``: ``greedy_generate`` was replaced by the
  serve engine (``resolve_serve_engine(...).run(...)``); only the
  compatibility shim in ``launch/serve.py`` may reference it.
* RPL402 ``legacy-init-cache``: ``init_cache`` takes ``(batch, max_len,
  cfg=...)``; the legacy cfg-first positional order is shimmed with a
  DeprecationWarning and must not gain callers — including the
  ``getattr(lm, "init_cache")(cfg, ...)`` spelling that dodges greps.
* RPL403 ``pythonpath-runline``: module docstrings must not advertise
  ``PYTHONPATH=src python ...`` run-lines — the package is pip-installed
  (``pip install -e .``); stale run-lines in docs rot silently because
  nothing executes them.
"""
from __future__ import annotations

import ast
import re

from ..engine import Rule, const_str, terminal_name

_PYTHONPATH_RUNLINE = re.compile(r"PYTHONPATH=src\s+python")


class GreedyGenerateRule(Rule):
    """No new greedy_generate call sites or imports outside the shim."""
    id = "RPL401"
    name = "greedy-generate"
    description = ("greedy_generate is deprecated — use "
                   "resolve_serve_engine(...).run(...); only the "
                   "launch/serve.py shim may reference it")
    allowed_suffix = "repro/launch/serve.py"

    def check(self, ctx, project):
        if ctx.path.endswith(self.allowed_suffix):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.Name, ast.Attribute)) and \
                    terminal_name(node) == "greedy_generate":
                yield self.finding(
                    ctx, node,
                    "references deprecated `greedy_generate` — use "
                    "`resolve_serve_engine(cfg).run(...)` (the serve "
                    "engine's one-call path)")
            elif isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    if alias.name == "greedy_generate":
                        yield self.finding(
                            ctx, node,
                            "imports deprecated `greedy_generate` — import "
                            "`resolve_serve_engine` instead")


class LegacyInitCacheRule(Rule):
    """No new cfg-first init_cache callers while the shim exists."""
    id = "RPL402"
    name = "legacy-init-cache"
    description = ("init_cache(cfg, ...) legacy argument order is "
                   "deprecated — call init_cache(batch, max_len, cfg=cfg)")
    cfg_names = frozenset({"cfg", "config", "model_cfg", "model_config"})
    allowed_suffix = "repro/models/lm.py"

    def _callee_is_init_cache(self, func: ast.AST) -> bool:
        if terminal_name(func) == "init_cache":
            return True
        # getattr(lm, "init_cache") — the grep-evading spelling
        return (isinstance(func, ast.Call)
                and terminal_name(func.func) == "getattr"
                and len(func.args) >= 2
                and const_str(func.args[1]) == "init_cache")

    def check(self, ctx, project):
        if ctx.path.endswith(self.allowed_suffix):
            return
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and self._callee_is_init_cache(node.func)
                    and node.args):
                continue
            if terminal_name(node.args[0]) in self.cfg_names:
                yield self.finding(
                    ctx, node,
                    "calls init_cache with the legacy cfg-first argument "
                    "order (shimmed with a DeprecationWarning) — use "
                    "`init_cache(batch, max_len, cfg=cfg)`")


class PythonpathRunlineRule(Rule):
    """Module docstrings must not advertise PYTHONPATH=src run-lines."""
    id = "RPL403"
    name = "pythonpath-runline"
    description = ("docstring run-lines must not use `PYTHONPATH=src "
                   "python ...` — the package is installed (pip install "
                   "-e .)")

    def check(self, ctx, project):
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.Module, ast.ClassDef,
                                     ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            body = getattr(node, "body", [])
            if not (body and isinstance(body[0], ast.Expr)
                    and const_str(body[0].value) is not None):
                continue
            doc_node = body[0].value
            # anchor the finding on the offending physical line: the
            # literal's lineno is its opening line, and the docstring's
            # Nth content line sits N lines below it (content starting on
            # the opening line has a leading segment at offset 0)
            start = doc_node.lineno
            for offset, text in enumerate(doc_node.value.splitlines()):
                if _PYTHONPATH_RUNLINE.search(text):
                    anchor = ast.Constant(value=None)
                    anchor.lineno = start + offset
                    anchor.col_offset = 0
                    yield self.finding(
                        ctx, anchor,
                        "docstring advertises a `PYTHONPATH=src python ...` "
                        "run-line — the package installs with `pip install "
                        "-e .`; document the bare `python -m ...` "
                        "invocation")
