"""Kernel-contract rules (RPL301/RPL302/RPL303).

docs/KERNELS.md's contracts, machine-checked:

* RPL301 ``kernel-vjp``: a kernel module under ``src/repro/kernels/``
  that exposes a ``*_pallas`` entry point must register a differentiable
  backward — a ``jax.custom_vjp`` wiring plus a ``.defvjp(...)`` call —
  so the entry is a real training path, not forward-only (the
  conv/pool/dense pattern).  Forward-only kernels awaiting their
  backward (ROADMAP "LM-family kernels" item) carry an explicit
  suppression at the entry def, so the debt is visible at the site.

* RPL302 ``silent-fallback``: inside a dispatch function, the
  ``if impl == "pallas":`` suite must either serve the call (every
  terminal path returns/raises) or route through the ``_fallback``
  contract (warn-once + ``fallback_events`` log, raise under explicit
  ``impl="pallas"``).  Falling off the suite into a bare ``return
  ref(...)`` tail is the silent-fallback bug class PR 5 closed.

* RPL303 ``kernel-unrouted``: every ``*_pallas`` entry point must be
  dispatched by the sibling ``ops.py`` — callers go through ``ops`` (the
  single REPRO_KERNEL_IMPL switch + planner hook), never straight to a
  kernel module.
"""
from __future__ import annotations

import ast
from pathlib import Path

from ..engine import Rule, terminal_name

_EXCLUDED = {"ops.py", "ref.py", "__init__.py"}


def _is_kernel_module(ctx) -> bool:
    p = Path(ctx.path)
    return ("kernels" in p.parts and p.name not in _EXCLUDED
            and p.suffix == ".py")


def _entry_defs(tree: ast.AST) -> list[ast.FunctionDef]:
    return [n for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef)
            and n.name.endswith("_pallas")]


def _has_pallas_call(tree: ast.AST) -> bool:
    return any(isinstance(n, ast.Call)
               and terminal_name(n.func) == "pallas_call"
               for n in ast.walk(tree))


class KernelVjpRule(Rule):
    """Every pallas_call entry point pairs with custom_vjp + defvjp."""
    id = "RPL301"
    name = "kernel-vjp"
    description = ("*_pallas entry points in src/repro/kernels/ must "
                   "register a custom_vjp backward via defvjp")

    def check(self, ctx, project):
        if not _is_kernel_module(ctx) or not _has_pallas_call(ctx.tree):
            return
        has_custom_vjp = False
        has_defvjp = False
        for n in ast.walk(ctx.tree):
            if isinstance(n, ast.Call):
                tn = terminal_name(n.func)
                if tn == "defvjp":
                    has_defvjp = True
                elif tn == "custom_vjp":
                    has_custom_vjp = True
                elif tn == "partial" and n.args and \
                        terminal_name(n.args[0]) == "custom_vjp":
                    has_custom_vjp = True
            elif isinstance(n, (ast.Name, ast.Attribute)) and \
                    terminal_name(n) == "custom_vjp":
                has_custom_vjp = True
        if has_custom_vjp and has_defvjp:
            return
        for entry in _entry_defs(ctx.tree):
            yield self.finding(
                ctx, entry,
                f"`{entry.name}` wraps a pallas_call but the module "
                "registers no custom_vjp+defvjp backward — the kernel is "
                "forward-only and cannot serve a training path (see "
                "docs/KERNELS.md)")


class KernelRoutedRule(Rule):
    """Every *_pallas entry point is dispatched by the sibling ops.py."""
    id = "RPL303"
    name = "kernel-unrouted"
    description = ("*_pallas entry points must be called by the sibling "
                   "ops.py dispatch (the single REPRO_KERNEL_IMPL switch)")

    def check(self, ctx, project):
        if not _is_kernel_module(ctx):
            return
        entries = _entry_defs(ctx.tree)
        if not entries:
            return
        ops = project.sibling(ctx, "ops.py")
        if ops is None or ops.tree is None:
            return                  # fixture trees without an ops.py
        called = {terminal_name(n.func) for n in ast.walk(ops.tree)
                  if isinstance(n, ast.Call)}
        for entry in entries:
            if entry.name not in called:
                yield self.finding(
                    ctx, entry,
                    f"`{entry.name}` is not dispatched by ops.py — kernel "
                    "entry points must route through the ops layer "
                    "(REPRO_KERNEL_IMPL switch, planner hook, fallback "
                    "contract)")


def _mentions_impl_pallas(test: ast.AST) -> bool:
    """True for a test comparing an `impl`-named value to "pallas"."""
    for n in ast.walk(test):
        if isinstance(n, ast.Compare):
            parts = [n.left, *n.comparators]
            names = {terminal_name(p) for p in parts}
            consts = {p.value for p in parts
                      if isinstance(p, ast.Constant)}
            if "impl" in names and "pallas" in consts:
                return True
    return False


def _suite_serves_or_falls_back(body: list[ast.stmt]) -> bool:
    """The pallas suite is honest if it always leaves (return/raise) or
    it calls the ``_fallback`` contract before falling through."""
    for stmt in body:
        for n in ast.walk(stmt):
            if isinstance(n, ast.Call) and \
                    terminal_name(n.func) in ("_fallback", "fallback"):
                return True
    last = body[-1]
    return isinstance(last, (ast.Return, ast.Raise))


class SilentFallbackRule(Rule):
    """A pallas dispatch branch that can fall through to the ref without
    the ``_fallback`` contract is a silent fallback."""
    id = "RPL302"
    name = "silent-fallback"
    description = ("an `if impl == \"pallas\"` suite must return/raise on "
                   "every path or invoke the _fallback contract")

    def check(self, ctx, project):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.If) and \
                    _mentions_impl_pallas(node.test) and \
                    not _suite_serves_or_falls_back(node.body):
                yield self.finding(
                    ctx, node,
                    "pallas dispatch suite can fall through to the ref "
                    "silently — return the kernel result on every path or "
                    "call `_fallback(op, reason, explicit)` so the event "
                    "is warned and logged in fallback_events()")
