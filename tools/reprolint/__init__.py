"""reprolint — repo-specific static analysis for the BPT-CNN codebase.

Machine-checks the invariants the architecture depends on (single
dispatch decision points, trace hygiene for Eq. 8 timing, the kernel
custom_vjp/fallback contracts, deprecation bans, donation safety) with
a stdlib-``ast`` rule engine.  Run it as::

    python -m tools.reprolint src tests benchmarks examples

See docs/LINTING.md for the rule catalogue.
"""
from __future__ import annotations

from .engine import (FileContext, Finding, Project, Rule, lint_paths,
                     lint_source, lint_sources, render_json, render_text,
                     run_rules)
from .rules import ALL_RULES

__all__ = [
    "ALL_RULES", "FileContext", "Finding", "Project", "Rule",
    "lint_paths", "lint_source", "lint_sources",
    "render_json", "render_text", "run_rules",
]
