"""reprolint rule engine: file contexts, suppressions, runner, reporting.

The linter is a repo-specific static-analysis pass over Python ASTs
(stdlib ``ast`` only — no third-party deps, so it runs anywhere the repo
checks out).  A ``Rule`` sees one ``FileContext`` at a time plus the
``Project`` (for cross-file contracts like "kernel entry points must be
routed through ops.py") and yields ``Finding``s; the engine filters them
through per-line ``# reprolint: disable=RULE`` suppressions and renders
text or JSON.  Rule IDs (``RPL101``) and symbolic names
(``dispatch-train``) are interchangeable in suppressions and ``--rules``.

See docs/LINTING.md for the rule catalogue and the contract each rule
machine-checks.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Iterable, Iterator, Optional

__all__ = [
    "Finding", "FileContext", "Project", "Rule",
    "lint_paths", "lint_sources", "lint_source", "run_rules",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    rule: str          # "RPL101"
    name: str          # "dispatch-train"
    path: str          # file path as scanned (posix separators)
    line: int          # 1-indexed
    col: int           # 0-indexed (ast convention)
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} [{self.name}] {self.message}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


# ``# reprolint: disable=RPL101,kernel-vjp`` — suppresses the named rules
# for findings anchored on that physical line ("all" suppresses every rule)
_SUPPRESS = re.compile(r"#\s*reprolint:\s*disable=([A-Za-z0-9_\-, ]+)")


class FileContext:
    """One scanned file: source text, parsed AST, suppression map.

    ``tree`` is None when the file does not parse — the engine reports
    that as an unsuppressable ``RPL000`` finding instead of crashing.
    """

    def __init__(self, path: str, text: str):
        self.path = Path(path).as_posix()
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[SyntaxError] = None
        try:
            self.tree = ast.parse(text)
        except SyntaxError as e:            # pragma: no cover - defensive
            self.parse_error = e
        self.suppressions: dict[int, set[str]] = {}
        for lineno, line in enumerate(self.lines, 1):
            m = _SUPPRESS.search(line)
            if m:
                self.suppressions[lineno] = {
                    tok.strip() for tok in m.group(1).split(",") if tok.strip()}

    def suppressed(self, line: int, rule_id: str, rule_name: str) -> bool:
        toks = self.suppressions.get(line)
        return bool(toks) and bool(toks & {rule_id, rule_name, "all"})


class Project:
    """All scanned files + lazy access to sibling files a rule needs even
    when they were not part of the scanned path set (e.g. the kernel
    routing rule reads ``ops.py`` next to the kernel module)."""

    def __init__(self, contexts: list[FileContext], allow_disk: bool = True):
        self.contexts = contexts
        self.allow_disk = allow_disk
        self._by_path = {c.path: c for c in contexts}
        self._found: dict[str, Optional[FileContext]] = {}

    def sibling(self, ctx: FileContext, name: str) -> Optional[FileContext]:
        """The FileContext for ``name`` in ``ctx``'s directory — from the
        scanned set if present, else loaded from disk (disabled for
        in-memory fixture projects), else None."""
        want = (Path(ctx.path).parent / name).as_posix()
        got = self._by_path.get(want)
        if got is not None:
            return got
        if not self.allow_disk:
            return None
        p = Path(want)
        if p.is_file():
            c = FileContext(want, p.read_text())
            self._by_path[want] = c
            return c
        return None

    def find(self, suffix: str) -> Optional[FileContext]:
        """The FileContext whose path ends with ``suffix`` (posix, e.g.
        ``"launch/mesh.py"``) — the cross-FILE (not just cross-directory)
        twin of ``sibling``, used by whole-program rules like the RPL6xx
        mesh-axis resolution.  Scanned set first; on disk, resolved
        against every scanned file's ancestor directories (so linting
        ``tests/`` alone still finds ``src/repro/launch/mesh.py``
        through the repo root).  None when absent (fixture projects
        without the module)."""
        if suffix in self._found:
            return self._found[suffix]
        got = None
        for c in self.contexts:
            if c.path.endswith(suffix):
                got = c
                break
        if got is None and self.allow_disk:
            seen = set()
            for c in self.contexts:
                for parent in Path(c.path).resolve().parents:
                    if parent in seen:
                        continue
                    seen.add(parent)
                    # bounded probes, not a glob: the package layout is
                    # fixed (src/repro/<suffix>), plus the direct join for
                    # paths already inside the package
                    for cand in (parent / "src" / "repro" / suffix,
                                 parent / suffix):
                        if cand.is_file():
                            got = FileContext(cand.as_posix(),
                                              cand.read_text())
                            break
                    if got is not None:
                        break
                if got is not None:
                    break
        self._found[suffix] = got
        return got


class Rule:
    """Base rule: subclasses set ``id``/``name``/``description`` and
    implement ``check(ctx, project) -> Iterator[Finding]``."""
    id = "RPL000"
    name = "base"
    description = ""

    def check(self, ctx: FileContext,
              project: Project) -> Iterator[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, ctx: FileContext, node: ast.AST,
                message: str) -> Finding:
        return Finding(self.id, self.name, ctx.path,
                       getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


# ----------------------------------------------------------------------
# helpers shared by rules
# ----------------------------------------------------------------------
def terminal_name(node: ast.AST) -> Optional[str]:
    """The last dotted component of a Name/Attribute chain:
    ``cfg`` -> "cfg"; ``self.t.tc`` -> "tc"; ``jax.jit`` -> "jit"."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def const_str(node: ast.AST) -> Optional[str]:
    return node.value if (isinstance(node, ast.Constant)
                          and isinstance(node.value, str)) else None


# ----------------------------------------------------------------------
# runner
# ----------------------------------------------------------------------
def _select(rules, only: Optional[Iterable[str]],
            disable: Optional[Iterable[str]] = None):
    keys, dkeys = set(only or ()), set(disable or ())
    unknown = (keys | dkeys) - {k for r in rules for k in (r.id, r.name)}
    if unknown:
        raise ValueError(f"unknown rule(s): {sorted(unknown)}")
    picked = list(rules)
    if keys:
        picked = [r for r in picked if r.id in keys or r.name in keys]
    if dkeys:
        picked = [r for r in picked
                  if r.id not in dkeys and r.name not in dkeys]
    return picked


def run_rules(project: Project, rules,
              only: Optional[Iterable[str]] = None,
              disable: Optional[Iterable[str]] = None) -> list[Finding]:
    picked = _select(rules, only, disable)
    out: list[Finding] = []
    for ctx in project.contexts:
        if ctx.parse_error is not None:
            out.append(Finding(
                "RPL000", "parse-error", ctx.path,
                ctx.parse_error.lineno or 1, 0,
                f"file does not parse: {ctx.parse_error.msg}"))
            continue
        for rule in picked:
            for f in rule.check(ctx, project):
                if not ctx.suppressed(f.line, f.rule, f.name):
                    out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


def iter_py_files(paths: Iterable[str]) -> Iterator[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" not in f.parts:
                    yield f
        elif p.is_file() and p.suffix == ".py":
            yield p
        else:
            raise FileNotFoundError(
                f"reprolint: not a directory or python file: {p}")


def lint_paths(paths: Iterable[str], rules=None,
               only: Optional[Iterable[str]] = None,
               disable: Optional[Iterable[str]] = None) -> list[Finding]:
    """Lint files/directories on disk; returns sorted findings."""
    if rules is None:
        from .rules import ALL_RULES as rules
    ctxs = [FileContext(str(f), f.read_text()) for f in iter_py_files(paths)]
    return run_rules(Project(ctxs), rules, only, disable)


def lint_sources(sources: dict[str, str], rules=None,
                 only: Optional[Iterable[str]] = None,
                 disable: Optional[Iterable[str]] = None) -> list[Finding]:
    """Lint in-memory sources keyed by (fake) path — the fixture-test
    entry point: paths control file-scoped rule applicability, and
    sibling lookups (kernels/ops.py) resolve inside the dict."""
    if rules is None:
        from .rules import ALL_RULES as rules
    ctxs = [FileContext(p, s) for p, s in sources.items()]
    return run_rules(Project(ctxs, allow_disk=False), rules, only, disable)


def lint_source(source: str, path: str = "snippet.py", rules=None,
                only: Optional[Iterable[str]] = None,
                disable: Optional[Iterable[str]] = None) -> list[Finding]:
    """Lint one in-memory source string."""
    return lint_sources({path: source}, rules, only, disable)


def render_text(findings: list[Finding], files: int) -> str:
    lines = [f.format() for f in findings]
    lines.append(f"reprolint: {files} files, {len(findings)} findings")
    return "\n".join(lines)


def render_json(findings: list[Finding], files: int, rules=None) -> str:
    by_rule: dict[str, int] = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    report = {
        "files": files,
        "findings": [f.to_json() for f in findings],
        "by_rule": by_rule,
    }
    if rules is not None:
        # per-rule counts for every rule that RAN (zeroes included), so a
        # report reader can tell "clean under RPL601" from "never checked"
        report["rules"] = {r.id: {"name": r.name,
                                  "findings": by_rule.get(r.id, 0)}
                           for r in rules}
    return json.dumps(report, indent=2, sort_keys=True)
