#!/usr/bin/env python
"""Markdown intra-repo link checker (the CI docs job).

Scans markdown files for ``[text](target)`` links, ignores external
schemes (http/https/mailto) and pure anchors, resolves relative targets
against each file's directory, and fails listing every dangling path.

Usage: python tools/check_links.py [file_or_dir ...]
Defaults to README.md + docs/ when run from the repo root.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — plain target up to the first ')' or whitespace, or an
# <angle-wrapped> target (CommonMark's form for paths with spaces); images
# (![alt](target)) match too via the optional leading '!'.
_LINK = re.compile(
    r"!?\[[^\]]*\]\((?:<([^>]+)>|([^)\s]+))(?:\s+\"[^\"]*\")?\)")
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def iter_markdown(paths: list[Path]):
    """Expand args to markdown files; a bad argument is an error, not a
    silent skip — a typo'd CI invocation must fail, not pass vacuously."""
    for p in paths:
        if p.is_dir():
            yield from sorted(p.rglob("*.md"))
        elif p.is_file() and p.suffix == ".md":
            yield p
        else:
            raise FileNotFoundError(
                f"check_links: not a directory or markdown file: {p}")


def check_file(md: Path) -> list[str]:
    errors = []
    in_fence = False
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        if line.lstrip().startswith(("```", "~~~")):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        line = re.sub(r"`[^`]*`", "", line)    # inline code spans
        for m in _LINK.finditer(line):
            target = m.group(1) or m.group(2)
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md}:{lineno}: dangling link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    roots = [Path(a) for a in argv] or [Path("README.md"), Path("docs")]
    try:
        files = list(iter_markdown(roots))
    except FileNotFoundError as e:
        print(e, file=sys.stderr)
        return 2
    if not files:
        print("check_links: no markdown files found", file=sys.stderr)
        return 2
    errors = [e for md in files for e in check_file(md)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, {len(errors)} dangling links")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
