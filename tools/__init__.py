"""Repo tooling: standalone scripts (check_links) + the reprolint package."""
