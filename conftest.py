"""Root conftest: make ``repro`` importable straight from the checkout.

``pip install -e .`` is the supported path (see README.md); prepending
``src/`` unconditionally keeps ``python -m pytest`` testing THIS working
tree even when some other ``repro`` install exists (an editable install
resolves to the same tree, so this is harmless there), and kills the
historical ``PYTHONPATH=src`` hack.

The repo root itself is appended too, so the test suite can import the
in-tree tooling (``tools.reprolint`` — the single-decision-point and
deprecation tests assert through the linter).
"""
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
if _ROOT not in sys.path:
    sys.path.insert(1, _ROOT)
