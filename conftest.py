"""Root conftest: make ``repro`` importable straight from the checkout.

``pip install -e .`` is the supported path (see README.md); prepending
``src/`` unconditionally keeps ``python -m pytest`` testing THIS working
tree even when some other ``repro`` install exists (an editable install
resolves to the same tree, so this is harmless there), and kills the
historical ``PYTHONPATH=src`` hack.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
