"""End-to-end driver: train a ~100M-parameter CNN for a few hundred steps
with the full BPT-CNN stack (IDPA + AGWU + inner-layer parallelism).

This is the paper's own workload at the largest scale this container
sustains: Table-2 "case2" topology at 32px with a widened FC stack
(~100M params), 4 virtual heterogeneous nodes, a few hundred optimizer
steps.  Reports the accuracy trace, sync-wait and communication volume.

Run:  python examples/train_bpt_cnn.py [--steps 200]
(`pip install -e .` first; bare checkouts can prefix `PYTHONPATH=src`.)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bpt_trainer import BPTTrainer, TrainHooks
from repro.core.engine import ENGINES, engine_config
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.models.cnn import CNNConfig, cnn_accuracy, cnn_loss, init_cnn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=240,
                    help="total optimizer steps across all nodes")
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=3)
    ap.add_argument("--image-size", type=int, default=32)
    ap.add_argument("--fc-neurons", type=int, default=2000,
                    help="2000 -> ~100M params (paper case5-7 FC scale)")
    ap.add_argument("--strategy", choices=("sgwu", "agwu"), default="agwu")
    ap.add_argument("--engine", choices=sorted(ENGINES), default="",
                    help="select the outer-layer execution engine by name "
                    "(overrides --strategy/--device-outer; see "
                    "repro.core.engine.ENGINES)")
    ap.add_argument("--device-outer", action="store_true",
                    help="shard the node axis over a real `nodes` device "
                    "mesh (needs >= --nodes devices, e.g. XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4; falls back "
                    "to the fused vmap emulation otherwise)")
    ap.add_argument("--uneven-batches", action="store_true",
                    help="IDPA-proportional per-node batch loads "
                    "(padded+masked stripes; needs --strategy sgwu)")
    ap.add_argument("--small", action="store_true",
                    help="tiny demo (fast)")
    args = ap.parse_args(argv)

    if args.small:
        args.fc_neurons, args.image_size, args.steps = 256, 16, 60

    cfg = CNNConfig(name="case2-wide", image_size=args.image_size,
                    conv_layers=4, filters=4, fc_layers=3,
                    fc_neurons=args.fc_neurons)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    n = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
    print(f"[bpt-cnn] model: {cfg.conv_layers} conv + {cfg.fc_layers} fc, "
          f"{n/1e6:.1f}M params, {args.image_size}px")

    xs, ys = image_dataset(4000, size=args.image_size, seed=0)
    xe, ye = image_dataset(800, size=args.image_size, seed=7)
    eval_batch = {"images": jnp.asarray(xe), "labels": jnp.asarray(ye)}
    eval_fn = jax.jit(lambda p: cnn_accuracy(p, eval_batch, cfg))

    speeds = 1.0 + 0.5 * np.arange(args.nodes)
    rounds = max(1, args.steps // (args.nodes * args.local_steps))
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=args.nodes,
                     batches=min(3, rounds), frequencies=1.0 / speeds,
                     idpa_mode="balanced")
    common = dict(outer_nodes=args.nodes, optimizer="adamw",
                  learning_rate=1e-3, warmup_steps=10,
                  total_steps=args.steps, local_steps=args.local_steps,
                  uneven_batches=args.uneven_batches)
    if args.engine:     # engine selected by name through the engine API
        tc = TrainConfig(**engine_config(args.engine, **common))
    else:
        tc = TrainConfig(outer_strategy=args.strategy,
                         device_outer=args.device_outer, **common)
    trainer = BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}), params, ds,
                         tc, batch_size=32, eval_fn=eval_fn,
                         speed_factors=speeds)
    hooks = TrainHooks(on_round=lambda ev: print(
        f"[bpt-cnn]   event {ev.round + 1}: loss={ev.loss:.4f} "
        f"clock={ev.virtual_clock:.1f}s", flush=True))
    t0 = time.time()
    rep = trainer.train(rounds=rounds, hooks=hooks)
    print(f"[bpt-cnn] {rep.steps} pushes in {time.time()-t0:.0f}s wall "
          f"({rep.strategy}/{rep.backend} outer backend, "
          f"{len(jax.devices())} device(s))")
    if rep.fallback:
        print(f"[bpt-cnn] engine fallback: {rep.fallback}")
    print(f"[bpt-cnn] accuracy trace: "
          f"{[(round(t,1), round(a,3)) for t, a in rep.accuracies]}")
    print(f"[bpt-cnn] IDPA allocation (samples/node): {rep.allocation}")
    print(f"[bpt-cnn] sync_wait={rep.sync_wait:.2f}s (AGWU -> 0) "
          f"comm={rep.comm_bytes/2**20:.1f}MB")
    # sanity: beat 10-class chance.  AGWU applies m× more global updates
    # than SGWU in the same --steps budget, so it clears a higher bar.
    floor = 0.3 if rep.strategy == "agwu" else 0.15
    assert rep.accuracies[-1][1] > floor, "should beat 10-class chance"


if __name__ == "__main__":
    main()
