"""Quickstart: the paper's pipeline in ~60 lines.

Trains the paper's CNN (Table 2 scale, reduced images) on a heterogeneous
virtual cluster with IDPA partitioning and the AGWU asynchronous parameter
server, then compares against the synchronous SGWU strategy — reproducing
the headline claim (accuracy parity, zero synchronisation wait) at demo
scale.

Run:  python examples/quickstart.py
(`pip install -e .` first; bare checkouts can prefix `PYTHONPATH=src`.)
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bpt_trainer import BPTTrainer
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.models.cnn import CNNConfig, cnn_accuracy, cnn_loss, init_cnn


def main():
    # --- the paper's CNN (scaled to 16px for a CPU demo) ---
    cfg = CNNConfig(name="quickstart", image_size=16, conv_layers=2,
                    filters=8, fc_layers=2, fc_neurons=64)
    xs, ys = image_dataset(2000, size=16, seed=0)
    xe, ye = image_dataset(500, size=16, seed=42)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    eval_batch = {"images": jnp.asarray(xe), "labels": jnp.asarray(ye)}
    eval_fn = jax.jit(lambda p: cnn_accuracy(p, eval_batch, cfg))

    # --- a 4-node heterogeneous virtual cluster (speeds 1x..2.2x) ---
    speeds = np.array([1.0, 1.3, 1.7, 2.2])
    for strategy in ("sgwu", "agwu"):
        ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=4,
                         batches=3, frequencies=1.0 / speeds,
                         partitioning="idpa", idpa_mode="balanced")
        tc = TrainConfig(outer_strategy=strategy, outer_nodes=4,
                         optimizer="adamw", learning_rate=2e-3,
                         warmup_steps=10, total_steps=400, local_steps=4)
        trainer = BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}),
                             params, ds, tc, batch_size=64,
                             eval_fn=eval_fn, speed_factors=speeds)
        rep = trainer.train(rounds=10)
        s = rep.summary()
        print(f"{strategy.upper():5s} acc={s['final_acc']:.3f} "
              f"virtual_makespan={s['makespan']:.2f}s "
              f"sync_wait={s['sync_wait']:.2f}s comm={s['comm_MB']}MB "
              f"allocation={rep.allocation}")
    print("\nAGWU trains with zero synchronisation wait (the paper's point);"
          "\nIDPA gave the fast nodes proportionally more samples.")


if __name__ == "__main__":
    main()
