"""Gemma-2 27B — dense, local/global alternating attention, logit softcap
[arXiv:2408.00118]."""
import dataclasses

from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    arch_type="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    sliding_window=4096,           # local layers
    window_pattern=2,              # every 2nd layer global
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    activation="gelu",
    tie_embeddings=True,
    citation="arXiv:2408.00118 (Gemma 2)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=8, num_kv_heads=4,
        head_dim=32, d_ff=512, vocab_size=512, sliding_window=16)
