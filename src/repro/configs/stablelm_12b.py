"""StableLM-2-12B — dense decoder with GQA
[hf:stabilityai/stablelm-2-1_6b family / stablelm-2-12b]."""
import dataclasses

from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,                  # d_model / num_heads
    d_ff=13824,
    vocab_size=100352,
    tie_embeddings=False,
    citation="hf:stabilityai/stablelm-2-12b (model card)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512)
