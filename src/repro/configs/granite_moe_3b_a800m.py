"""Granite-3.0 MoE 3B-A800M — 40 experts top-8
[hf:ibm-granite/granite-3.0-3b-a800m-base family]."""
import dataclasses

from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=0,
    vocab_size=49155,
    num_experts=40,
    top_k=8,
    expert_d_ff=512,
    tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base (Granite 3.0 MoE)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, vocab_size=512, num_experts=4, top_k=2, expert_d_ff=128)
