"""InternVL2-26B — InternViT + InternLM2 VLM [arXiv:2404.16821].

LLM backbone only (InternLM2-20B-style decoder); the InternViT-6B vision
encoder + MLP projector is a stub providing precomputed patch embeddings
(assignment carve-out, DESIGN.md §4).
"""
import dataclasses

from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    arch_type="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    frontend="vision",
    num_frontend_tokens=256,       # ViT patch tokens per image
    tie_embeddings=False,
    citation="arXiv:2404.16821 (InternVL 1.5/2 report)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512, num_frontend_tokens=8)
