"""Architecture config registry: ``get_config(name)`` / ``get_reduced(name)``.

Every assigned architecture is selectable via ``--arch <id>`` in the
launchers.  ``LONG_CONTEXT_OK`` lists archs that run ``long_500k``
natively (sub-quadratic / sliding-window path); dense archs may opt in via
the ``swa`` variant (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.core.types import ModelConfig

from .shapes import SHAPES, get_shape  # noqa: F401  (get_shape re-exported)

_MODULES = {
    "yi-6b": "yi_6b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "gemma2-27b": "gemma2_27b",
    "mamba2-370m": "mamba2_370m",
    "internvl2-26b": "internvl2_26b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "stablelm-12b": "stablelm_12b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
}

ARCH_NAMES = tuple(_MODULES)

# archs whose long_500k decode runs without a variant flag
LONG_CONTEXT_OK = ("mamba2-370m", "hymba-1.5b", "gemma2-27b")

# shape skips (DESIGN.md §4): pure full-attention archs skip long_500k
SKIPS: dict[tuple[str, str], str] = {
    (arch, "long_500k"): "full-attention 500k decode (no sub-quadratic path)"
    for arch in ARCH_NAMES if arch not in LONG_CONTEXT_OK
}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str, variant: str = "") -> ModelConfig:
    cfg = _module(name).CONFIG
    if variant == "swa":
        # sliding-window variant for dense archs' long-context decode
        cfg = dataclasses.replace(cfg, sliding_window=4096, window_pattern=0,
                                  global_layers=())
    elif variant == "opt":
        # beyond-paper optimized config (EXPERIMENTS.md §Perf): seq-sharded
        # attention + banded window skipping
        cfg = dataclasses.replace(
            cfg, attn_kv_gather=True,
            attn_block_skip=cfg.sliding_window > 0)
    elif variant:
        raise ValueError(f"unknown variant {variant!r}")
    return cfg


def get_reduced(name: str) -> ModelConfig:
    return _module(name).reduced()


def pairs(include_skips: bool = False):
    """All (arch, shape) baseline pairs, minus documented skips."""
    out = []
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            if not include_skips and (arch, shape) in SKIPS:
                continue
            out.append((arch, shape))
    return out
