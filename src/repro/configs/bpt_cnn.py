"""The paper's own CNN configurations (Table 2, cases 1-7)."""
from repro.models.cnn import TABLE2_CASES, CNNConfig, make_case

__all__ = ["TABLE2_CASES", "get_case", "DEFAULT"]


def get_case(case: str = "case2", image_size: int = 32,
             num_classes: int = 10) -> CNNConfig:
    return make_case(case, image_size=image_size, num_classes=num_classes)


DEFAULT = get_case("case2")
