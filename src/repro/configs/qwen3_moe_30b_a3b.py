"""Qwen3-30B-A3B — MoE decoder, 128 experts top-8, QK-norm
[hf:Qwen/Qwen3-30B-A3B]."""
import dataclasses

from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,                        # every FFN is MoE
    vocab_size=151936,
    num_experts=128,
    top_k=8,
    expert_d_ff=768,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    citation="hf:Qwen/Qwen3-30B-A3B (Qwen3 model card)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, vocab_size=512, num_experts=4, top_k=2, expert_d_ff=128)
