"""Hymba-1.5B — hybrid parallel attention + mamba heads [arXiv:2411.13676].

Each block runs GQA attention and an SSD mixer *in parallel* on the same
input, with per-branch output norms and learned mixing (models/blocks.py).
Meta-tokens are omitted (prompt-side trick, not a backbone property).
Per the Hymba recipe, most layers use sliding-window attention; first,
middle and last layers stay global.
"""
import dataclasses

from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_heads=25,
    ssm_head_dim=128,              # d_inner = 3200 = 2 * d_model
    ssm_expand=2,
    conv_kernel=4,
    sliding_window=1024,
    global_layers=(0, 15, 31),     # full-attention layers
    tie_embeddings=True,
    citation="arXiv:2411.13676 (Hymba: Hybrid-head Architecture)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512, ssm_heads=4, ssm_head_dim=32,
        ssm_state=16, sliding_window=16, global_layers=(0,))
