"""SeamlessM4T-large-v2 — enc-dec multimodal backbone [arXiv:2308.11596].

Transformer backbone only: the speech frontend (mel + conformer feature
extractor) is a stub providing precomputed frame embeddings (assignment
carve-out, DESIGN.md §4).
"""
import dataclasses

from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="encdec",
    num_layers=24,                 # decoder
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,               # MHA (GQA kv=16 == heads)
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio",
    num_frontend_tokens=4096,      # encoder frames (stub embeddings)
    tie_embeddings=True,
    citation="arXiv:2308.11596 (SeamlessM4T v2)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, num_encoder_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        num_frontend_tokens=16)
