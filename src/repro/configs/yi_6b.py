"""Yi-6B — llama-architecture dense decoder with GQA [arXiv:2403.04652]."""
import dataclasses

from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    citation="arXiv:2403.04652 (Yi: Open Foundation Models by 01.AI)",
)


def reduced() -> ModelConfig:
    """Same family, smoke-test scale (2L, d_model<=512)."""
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512)
