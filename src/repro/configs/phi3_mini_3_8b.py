"""Phi-3-mini 3.8B — dense decoder, RoPE + SwiGLU + GQA [arXiv:2404.14219]."""
import dataclasses

from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,               # per assignment: GQA kv=32 (== MHA)
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    tie_embeddings=False,
    citation="arXiv:2404.14219 (Phi-3 Technical Report)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=256, num_heads=8, num_kv_heads=8,
        head_dim=32, d_ff=512, vocab_size=512)
