"""Mamba2-370M — attention-free SSM with state-space duality
[arXiv:2405.21060]."""
import dataclasses

from repro.core.types import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,                   # attention-free
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                        # no MLP (mamba2 blocks are mixer-only)
    vocab_size=50280,
    ssm_state=128,                 # N
    ssm_heads=32,                  # H (d_inner 2048 / P 64)
    ssm_head_dim=64,               # P
    ssm_expand=2,
    conv_kernel=4,
    tie_embeddings=True,
    citation="arXiv:2405.21060 (Transformers are SSMs: Mamba-2 / SSD)",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, ssm_heads=4, ssm_head_dim=32,
        ssm_state=16, vocab_size=512)
