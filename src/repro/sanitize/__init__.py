"""repro.sanitize — runtime sanitizer (transfer guards + compile budgets).

The dynamic half of shardcheck; the static half is the RPL6xx rule
family in ``tools/reprolint``.  See ``harness`` for the full contract.
"""
from .harness import (CompileBudgetExceeded, clear_sync_log, compile_budget,
                      compile_counts, install_compile_listener,
                      sanctioned_scope, sanctioned_sync, sanitize_enabled,
                      sanitized, sync_log)

__all__ = [
    "sanitize_enabled", "sanitized", "sanctioned_scope", "sanctioned_sync",
    "sync_log", "clear_sync_log",
    "install_compile_listener", "compile_counts", "compile_budget",
    "CompileBudgetExceeded",
]
