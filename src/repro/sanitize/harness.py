"""Runtime sanitizer — shardcheck's dynamic half (see docs/LINTING.md).

The static rules (RPL2xx/RPL6xx) prove what the AST can prove; this
module catches the two failure classes that only exist at run time:

* **Hidden transfers.**  ``sanitized()`` arms ``jax.transfer_guard`` so
  that any *implicit* host<->device transfer inside an engine round —
  a numpy batch silently uploaded at jit dispatch, a device value
  silently pulled by host arithmetic — raises instead of serializing
  the pipeline.  Host syncs that are *supposed* to happen (the Eq. 8
  measured-wall boundary, accuracy evals feeding Eq. 7/10) route
  through ``sanctioned_sync()`` / ``sanctioned_scope()``: the one
  audited escape hatch, mirrored on the static side by RPL201's
  allowlist.
* **Silent recompiles.**  A compile-event counter built on
  ``jax.monitoring`` duration events (which fire only on real
  compilations, never on cached dispatches) backs ``compile_budget(n)``
  assertions — steady-state code paths pin a budget of 0 new compiles,
  the same contract ``ServeEngine.prefill_traces`` enforces per
  function (PR 8 pattern).

Everything is gated on ``REPRO_SANITIZE`` (off by default; the CI tier-1
matrix runs a ``REPRO_SANITIZE=1`` leg).  With the gate off, ``sanitized``
is a no-op and ``sanctioned_sync`` still blocks + materializes — callers
never branch on the env var themselves.

Backend honesty note: on the CPU backend device arrays are host-resident,
so the device-to-host half of the guard never fires there — CPU CI
enforces the implicit host-to-device class (dispatch hygiene) and the
d2h half arms automatically on real accelerators.
"""
from __future__ import annotations

import contextlib
import os
import threading

import jax
import numpy as np

__all__ = [
    "sanitize_enabled", "sanitized", "sanctioned_scope", "sanctioned_sync",
    "sync_log", "clear_sync_log",
    "install_compile_listener", "compile_counts", "compile_budget",
    "CompileBudgetExceeded",
]


def sanitize_enabled() -> bool:
    """True when the REPRO_SANITIZE env gate is on ("", "0", "off" = off)."""
    return os.environ.get("REPRO_SANITIZE", "").lower() not in ("", "0", "off")


@contextlib.contextmanager
def sanitized(label: str = ""):
    """Arm the transfer guards around an engine round body.

    Inside the scope every implicit host-to-device transfer (numpy
    leaves reaching a jit dispatch, weak python scalars promoted at call
    time) and every implicit device-to-host transfer raises
    ``jax.errors.JaxRuntimeError``.  Explicit placements
    (``jax.device_put``, ``jnp.asarray``) stay legal — the point is that
    every transfer is *visible in the code*, not that no data moves.
    No-op when ``REPRO_SANITIZE`` is off.
    """
    if not sanitize_enabled():
        yield
        return
    with jax.transfer_guard_host_to_device("disallow"), \
            jax.transfer_guard_device_to_host("disallow"):
        yield


# audit trail of sanctioned sync points, most recent last: (label,) tuples
# are enough for tests to assert "the only syncs were the measured ones"
_sync_log: list = []
_sync_lock = threading.Lock()


def sync_log() -> list:
    """Labels of every sanctioned sync since the last clear (copy)."""
    with _sync_lock:
        return list(_sync_log)


def clear_sync_log() -> None:
    with _sync_lock:
        _sync_log.clear()


@contextlib.contextmanager
def sanctioned_scope(label: str):
    """The audited escape hatch: transfers are allowed inside, and the
    scope is recorded in ``sync_log()``.  Use it where a host sync IS
    the semantics — measured-wall boundaries (``MeasuredTimer``),
    accuracy evals whose scalar feeds Eq. 7/10 weighting."""
    with jax.transfer_guard("allow"):
        yield
    with _sync_lock:
        _sync_log.append(label)


def sanctioned_sync(x, label: str = "sync"):
    """Block on ``x`` and materialize it on host, as a sanctioned sync.

    The runtime twin of RPL201's allowlist: engine code that must pull a
    device value (per-node losses for ``RoundEvent``, eval scalars)
    calls this instead of raw ``np.asarray(jax.block_until_ready(...))``
    so the pull stays legal under ``sanitized()`` and lands in the audit
    log.  Returns the pytree with every leaf as ``np.ndarray``.
    """
    with sanctioned_scope(label):
        out = jax.block_until_ready(x)
        return jax.tree_util.tree_map(np.asarray, out)


# ----------------------------------------------------------------------
# compile budgets
# ----------------------------------------------------------------------
class CompileBudgetExceeded(AssertionError):
    """A ``compile_budget`` scope compiled more than it promised."""


# jax.monitoring duration events that fire ONLY on real compilations
# (cached dispatches emit nothing).  One XLA compilation emits >= 1
# backend_compile event and >= 1 trace event — treat the counts as
# "compile activity", not an exact compilation count: budgets are upper
# bounds, and the load-bearing assertion is the steady-state budget of 0.
_TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"
_BACKEND_EVENT = "/jax/core/compile/backend_compile_duration"

_counts = {"traces": 0, "compiles": 0}
_listening = False


def _on_duration(event: str, secs: float, **kw) -> None:
    if event == _TRACE_EVENT:
        _counts["traces"] += 1
    elif event == _BACKEND_EVENT:
        _counts["compiles"] += 1


def install_compile_listener() -> None:
    """Register the compile-event listener (idempotent, process-wide).

    ``jax.monitoring`` has no unregister, so the listener stays for the
    life of the process — it only bumps two ints per compilation.
    """
    global _listening
    if _listening:
        return
    from jax import monitoring
    monitoring.register_event_duration_secs_listener(_on_duration)
    _listening = True


def compile_counts() -> dict:
    """Cumulative compile-activity counters since the listener install:
    ``traces`` (jaxpr traces) and ``compiles`` (XLA backend compiles)."""
    return dict(_counts)


@contextlib.contextmanager
def compile_budget(n: int, what: str = "compiles", label: str = ""):
    """Assert the scope triggers at most ``n`` compile events.

    ``what`` selects the counter ("compiles" = XLA backend compilations,
    "traces" = jaxpr traces).  ``compile_budget(0)`` is the steady-state
    contract: a warmed code path must dispatch from cache.  Raises
    ``CompileBudgetExceeded`` (an AssertionError) on overrun.
    """
    install_compile_listener()
    before = _counts[what]
    yield
    spent = _counts[what] - before
    if spent > n:
        where = f" [{label}]" if label else ""
        raise CompileBudgetExceeded(
            f"compile budget exceeded{where}: {spent} {what} > "
            f"budget {n} — a warmed path recompiled (shape/dtype drift or "
            "a python-object hash miss in jit static args)")
