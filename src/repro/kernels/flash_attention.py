"""Pallas TPU flash-attention kernel (blockwise online softmax).

Inner-layer task parallelism for transformer blocks: the grid cell is one
(batch, head, q-tile) task; the sequential innermost kv axis performs the
online-softmax accumulation in VMEM scratch.  Supports GQA (kv-head
index_map h -> h // G), causal masking, sliding windows and gemma-2 attn
logit soft-capping — the same semantics as ``models.attention``'s jnp path
and ``ref.attention_ref``.

Layouts: q (B, H, Sq, D);  k, v (B, KH, Sk, D);  out (B, H, Sq, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  tq: int, tk: int, nk: int, causal: bool, window: int,
                  softcap: float, scale: float, sq: int, sk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # (tq, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (tk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
    k_pos = ki * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    mask = k_pos < sk                                  # kv padding
    mask &= q_pos < sq
    if causal:
        mask &= k_pos <= q_pos + (sk - sq)
    if window:
        mask &= (q_pos + (sk - sq)) - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


# forward-only for now: the fused backward is the ROADMAP "LM-family
# kernels" item — training falls back to the ref path via ops.attention
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,  # reprolint: disable=RPL301
                           softcap: float = 0.0, q_tile: int = 128,
                           k_tile: int = 128, interpret: bool | None = None):
    """q: (B,H,Sq,D); k,v: (B,KH,Sk,D) -> (B,H,Sq,D).

    ``interpret=None`` resolves via ``ops._interpret()`` (compiled on TPU).
    """
    interpret = resolve_interpret(interpret)
    B, H, Sq, D = q.shape
    KH, Sk = k.shape[1], k.shape[2]
    G = H // KH
    tq, tk = min(q_tile, Sq), min(k_tile, Sk)
    nq, nk = -(-Sq // tq), -(-Sk // tk)
    # pad sequences to tile multiples
    if nq * tq != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, nq * tq - Sq), (0, 0)))
    if nk * tk != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, nk * tk - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, nk * tk - Sk), (0, 0)))

    kern = functools.partial(
        _flash_kernel, tq=tq, tk=tk, nk=nk, causal=causal, window=window,
        softcap=softcap, scale=1.0 / float(D) ** 0.5, sq=Sq, sk=Sk)
    out = pl.pallas_call(
        kern,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, tq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, tk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, tk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * tq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, D), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
