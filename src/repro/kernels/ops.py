"""Jit'd public wrappers over the Pallas kernels with jnp-ref fallbacks.

Implementation selection:
  * ``REPRO_KERNEL_IMPL=ref``    — pure-jnp oracles (default on CPU; what
    the 512-device dry-run lowers).
  * ``REPRO_KERNEL_IMPL=pallas`` — Pallas kernels (interpret mode off TPU,
    compiled on TPU).  ``conv2d`` is fully differentiable through its
    ``custom_vjp`` backward kernels, so this is a real training path.

Kernel entry points take ``interpret=None`` and resolve it through
``_interpret()`` here — the single switch that decides interpret-vs-compiled
— so no call site can silently ship interpret-mode kernels to a TPU.

``conv2d``'s default ``oc_tile`` comes from ``core.dag.choose_oc_tile``:
the paper's task-decomposition cost model (Alg. 4.2 list scheduling over
the candidate PT_Conv grids) picks the output-channel tile the executed
Pallas grid uses, keeping decomposition and execution one concept.
"""
from __future__ import annotations

import os

import jax

from . import ref
from .conv2d import conv2d_pallas
from .flash_attention import flash_attention_pallas
from .rmsnorm import rmsnorm_pallas

__all__ = ["conv2d", "max_pool2d", "flash_attention", "rmsnorm",
           "default_impl"]


def default_impl() -> str:
    impl = os.environ.get("REPRO_KERNEL_IMPL", "")
    if impl:
        return impl
    return "ref" if jax.default_backend() == "cpu" else "pallas"


def _interpret() -> bool:
    """Interpret-mode switch: compiled kernels only on real TPU silicon."""
    return jax.default_backend() != "tpu"


def conv2d(x, w, b=None, padding: str = "SAME", stride: int = 1,
           activation: str = "none", impl: str = "",
           oc_tile: int | None = None):
    """Conv + optional fused bias/activation epilogue (paper Eq. 1+2).

    The Pallas path (stride 1) is differentiable end-to-end via
    ``custom_vjp``; ``oc_tile=None`` asks the §4 cost model for the task
    granularity, ``oc_tile=0`` forces one task per batch image.
    """
    impl = impl or default_impl()
    if impl == "pallas" and stride == 1:
        if oc_tile is None:
            from repro.core.dag import choose_oc_tile
            oc_tile = choose_oc_tile(int(x.shape[0]), int(w.shape[-1]))
        return conv2d_pallas(x, w, b, padding=padding, activation=activation,
                             oc_tile=oc_tile, interpret=_interpret())
    out = ref.conv2d_ref(x, w, padding=padding, stride=stride)
    if b is not None:
        out = out + b.astype(out.dtype)    # match the kernel's output dtype
    if activation == "relu":
        out = jax.nn.relu(out)
    elif activation != "none":
        raise ValueError(activation)
    return out


def max_pool2d(x, window: int = 2, stride: int = 2):
    return ref.max_pool2d_ref(x, window=window, stride=stride)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    impl: str = ""):
    """q: (B,Sq,H,D); k,v: (B,Sk,KH,D) — BSHD layout like the models."""
    impl = impl or default_impl()
    if impl == "pallas":
        out = flash_attention_pallas(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
            softcap=softcap, interpret=_interpret())
        return out.transpose(0, 2, 1, 3)
    return ref.attention_ref(q, k, v, causal=causal,
                             window=window or None, softcap=softcap)


def rmsnorm(x, scale, eps: float = 1e-6, impl: str = ""):
    impl = impl or default_impl()
    if impl == "pallas":
        return rmsnorm_pallas(x, scale, eps=eps, interpret=_interpret())
    return ref.rmsnorm_ref(x, scale, eps=eps)
