"""Jit'd public wrappers over the Pallas kernels with jnp-ref fallbacks.

Implementation selection:
  * ``REPRO_KERNEL_IMPL=ref``    — pure-jnp oracles (default on CPU; what
    the 512-device dry-run lowers).
  * ``REPRO_KERNEL_IMPL=pallas`` — Pallas kernels (interpret mode off TPU,
    compiled on TPU).  ``conv2d``, ``max_pool2d`` and ``dense`` are fully
    differentiable through their ``custom_vjp`` backward kernels, so the
    whole CNN forward+backward (conv Eq. 13, pooling Eq. 15/18, FC
    Eq. 19-21) is a real Pallas training path.

Kernel entry points take ``interpret=None`` and resolve it through
``_interpret()`` here — the single switch that decides interpret-vs-compiled
— so no call site can silently ship interpret-mode kernels to a TPU.

**Fallback contract**: when the pallas impl cannot serve a call (e.g. a
strided conv, overlapping pooling, a dense cell too large for VMEM) the
fallback to the jnp ref is never silent.  An explicit ``impl="pallas"``
argument raises ``NotImplementedError``; an environment/default-selected
pallas impl warns once per (op, reason) with ``KernelFallbackWarning`` and
records the event in ``fallback_events()`` — tests assert the log stays
empty on the paths that must be all-Pallas.  Dispatch happens in Python,
so events are recorded at *trace* time: one entry per traced call site,
not per compiled execution (re-running an already-jitted function records
nothing new — assert on the log in eager code or around fresh traces).

Default task granularities come from ``core.dag``'s Alg. 4.2 cost model —
``conv2d``'s ``oc_tile`` from ``choose_oc_tile`` and ``dense``'s ``block``
from ``choose_fc_block`` — so the paper's task decomposition and the
executed Pallas grids stay one concept.

**Planner hook**: inside an active ``core.planner.plan_scope`` (the 2-D
``(nodes, model)`` rounds of ``ShardMapEngine``) the tile knobs come from
the per-layer ``LayerPlan`` instead — the plan's tiles were chosen by the
same Alg. 4.2 model on the post-sharding LOCAL shapes, so scheduled and
executed grids stay one concept under the hybrid mesh too.  A ``channel``
fc plan additionally reroutes ``dense`` through the Megatron column-
parallel dataflow (``rep_in``/``shard_dim``/``gather_cols``).  With no
scope active (every 1-D / fused / eval path) behavior is unchanged.
"""
from __future__ import annotations

import os
import warnings

import jax

from . import ref
from .conv2d import conv2d_pallas
from .dense import dense_pallas
from .flash_attention import flash_attention_pallas
from .pool2d import max_pool2d_pallas
from .rmsnorm import rmsnorm_pallas

__all__ = ["conv2d", "max_pool2d", "dense", "flash_attention", "rmsnorm",
           "default_impl", "KernelFallbackWarning", "fallback_events",
           "clear_fallback_log"]


def default_impl() -> str:
    impl = os.environ.get("REPRO_KERNEL_IMPL", "")
    if impl:
        return impl
    return "ref" if jax.default_backend() == "cpu" else "pallas"


def _interpret() -> bool:
    """Interpret-mode switch: compiled kernels only on real TPU silicon."""
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------------
# the explicit-fallback contract
# ----------------------------------------------------------------------
class KernelFallbackWarning(UserWarning):
    """A pallas-selected dispatch served a call from the jnp ref."""


_FALLBACKS: dict[tuple[str, str], int] = {}


def _fallback(op: str, reason: str, explicit: bool) -> None:
    """Record a pallas -> ref fallback; never silent.

    ``explicit`` (the caller passed ``impl="pallas"``) raises — the caller
    asked for a kernel that cannot serve the call.  An env/default-selected
    pallas impl warns once per (op, reason) and logs the event.
    """
    if explicit:
        raise NotImplementedError(
            f"{op}: impl='pallas' was requested explicitly but {reason}; "
            "pass impl='ref' (or fix the call) to opt in to the jnp "
            "reference instead")
    key = (op, reason)
    first = key not in _FALLBACKS
    _FALLBACKS[key] = _FALLBACKS.get(key, 0) + 1
    if first:
        warnings.warn(f"{op}: falling back to the jnp ref — {reason}",
                      KernelFallbackWarning, stacklevel=3)


def fallback_events() -> dict[tuple[str, str], int]:
    """(op, reason) -> count of pallas dispatches served by the ref."""
    return dict(_FALLBACKS)


def clear_fallback_log() -> None:
    _FALLBACKS.clear()


def _plan_take(kind: str):
    """The active LayerPlan for the next ``kind`` call, or None."""
    from repro.core import planner
    return planner.take(kind)


# ----------------------------------------------------------------------
# dispatch wrappers
# ----------------------------------------------------------------------
def conv2d(x, w, b=None, padding: str = "SAME", stride: int = 1,
           activation: str = "none", impl: str = "",
           oc_tile: int | None = None):
    """Conv + optional fused bias/activation epilogue (paper Eq. 1+2).

    The Pallas path (stride 1) is differentiable end-to-end via
    ``custom_vjp``; ``oc_tile=None`` asks the §4 cost model for the task
    granularity, ``oc_tile=0`` forces one task per batch image.  A strided
    call under pallas takes the explicit-fallback contract (the paper's
    CNNs pool instead of striding, so the kernel is stride-1 only).
    """
    if oc_tile is None:
        lp = _plan_take("conv")
        if lp is not None:
            oc_tile = lp.tile
    explicit = impl == "pallas"
    impl = impl or default_impl()
    if impl == "pallas":
        if stride == 1:
            if oc_tile is None:
                from repro.core.dag import choose_oc_tile
                oc_tile = choose_oc_tile(int(x.shape[0]), int(w.shape[-1]))
            return conv2d_pallas(x, w, b, padding=padding,
                                 activation=activation, oc_tile=oc_tile,
                                 interpret=_interpret())
        _fallback("conv2d",
                  f"stride={stride} is unsupported (stride-1 kernel only)",
                  explicit)
    out = ref.conv2d_ref(x, w, padding=padding, stride=stride)
    if b is not None:
        out = out + b.astype(out.dtype)    # match the kernel's output dtype
    if activation == "relu":
        out = jax.nn.relu(out)
    elif activation != "none":
        raise ValueError(activation)
    return out


def max_pool2d(x, window: int = 2, stride: int = 2, impl: str = ""):
    """Max pooling (paper Eq. 15; backward Eq. 18 via ``custom_vjp``).

    The Pallas path covers non-overlapping pooling (``window == stride``,
    the paper's configuration); anything else takes the explicit-fallback
    contract.  Note the jnp ref is also non-overlapping-only today, so an
    overlapping env-selected fallback will raise there — loudly, after the
    recorded warning — rather than silently pool the wrong windows.
    """
    explicit = impl == "pallas"
    impl = impl or default_impl()
    if impl == "pallas":
        if window == stride:
            return max_pool2d_pallas(x, window=window, stride=stride,
                                     interpret=_interpret())
        _fallback("max_pool2d",
                  f"window={window} stride={stride} is unsupported "
                  "(non-overlapping pooling only)", explicit)
    return ref.max_pool2d_ref(x, window=window, stride=stride)


# Per-grid-cell VMEM budget for the dense kernel (bytes).  The kernel
# holds the whole flattened row block, one weight panel and one output
# panel per cell — fine for the paper's FC stacks, but a transformer-scale
# matmul (e.g. an LM head) would blow the ~16 MB VMEM; those calls take
# the explicit-fallback contract until the kernel grows row/K tiling.
_DENSE_VMEM_BUDGET = 8 * 2**20


def _dense_cell_bytes(rows: int, d_in: int, d_out: int, block: int,
                      itemsize: int) -> int:
    """Worst per-cell VMEM footprint across the three dense grids.

    fwd/dwdb cells hold the row block, one (Din, block) weight panel and
    one (rows, block) activation panel; the dx cell holds the full
    cotangent row block plus a (Dout, it) transposed-weight panel, where
    ``it`` is the derived Din tile (see ``dense._block_of``).
    """
    nt = block or d_out
    it = block if (block and d_in % block == 0) else d_in
    fwd = rows * d_in + d_in * nt + rows * nt
    dx = rows * d_out + d_out * it + rows * it
    return max(fwd, dx) * itemsize


def dense(x, w, b=None, activation: str = "none", impl: str = "",
          block: int | None = None):
    """Fused dense layer: x @ w (+ b) (+ activation), paper §4.1.2.

    ``x`` may carry leading batch dims — they flatten into the kernel's
    row axis and reshape back.  The Pallas path is differentiable via
    ``custom_vjp`` (per-block G_FC gradient tasks); ``block=None`` asks
    the Alg. 4.2 cost model (``core.dag.choose_fc_block``) for the task
    granularity, ``block=0`` forces one task for the whole layer.  A call
    whose grid cell would exceed ``_DENSE_VMEM_BUDGET`` takes the
    explicit-fallback contract.

    Under an active plan scope a ``channel``-parallel LayerPlan reroutes
    the call through the Megatron column dataflow: the weight/bias shard
    for this device's ``model`` index, the kernel on the local block
    (with the plan's LOCAL-shape tile), and a replication-aware
    all-gather back to the full activation — gradients stay exactly
    replicated across ``model`` via the collectives' custom VJPs.
    """
    if block is None:
        lp = _plan_take("fc")
        if lp is not None:
            block = lp.tile
            if lp.parallel_dim == "channel":
                from repro.core import planner
                full = int(w.shape[-1])
                xr = planner.rep_in(x, lp.axis)
                ws = planner.shard_dim(w, lp.shards, full, lp.axis)
                bs = planner.shard_dim(b, lp.shards, full, lp.axis) \
                    if b is not None else None
                out = dense(xr, ws, bs, activation=activation, impl=impl,
                            block=block)
                return planner.gather_cols(out, lp.shards, lp.axis)
    explicit = impl == "pallas"
    impl = impl or default_impl()
    if impl == "pallas":
        if block is None:
            from repro.core.dag import choose_fc_block
            block = choose_fc_block(int(w.shape[-1]))
        rows = 1
        for d in x.shape[:-1]:
            rows *= int(d)
        cell = _dense_cell_bytes(rows, int(x.shape[-1]), int(w.shape[-1]),
                                 int(block), x.dtype.itemsize)
        if cell <= _DENSE_VMEM_BUDGET:
            lead = x.shape[:-1]
            x2 = x.reshape(-1, x.shape[-1])
            # match the ref's compute dtype (w cast to the activations')
            out = dense_pallas(x2, w.astype(x.dtype), b,
                               activation=activation, block=block,
                               interpret=_interpret())
            return out.reshape(*lead, w.shape[-1])
        _fallback(
            "dense",
            f"grid cell of {cell / 2**20:.1f} MiB exceeds the "
            f"{_DENSE_VMEM_BUDGET / 2**20:.0f} MiB VMEM budget "
            "(kernel has no row/K tiling yet)", explicit)
    return ref.dense_ref(x, w, b, activation=activation)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    impl: str = ""):
    """q: (B,Sq,H,D); k,v: (B,Sk,KH,D) — BSHD layout like the models."""
    impl = impl or default_impl()
    if impl == "pallas":
        out = flash_attention_pallas(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
            softcap=softcap, interpret=_interpret())
        return out.transpose(0, 2, 1, 3)
    return ref.attention_ref(q, k, v, causal=causal,
                             window=window or None, softcap=softcap)


def rmsnorm(x, scale, eps: float = 1e-6, impl: str = ""):
    impl = impl or default_impl()
    if impl == "pallas":
        return rmsnorm_pallas(x, scale, eps=eps, interpret=_interpret())
    return ref.rmsnorm_ref(x, scale, eps=eps)
