"""Jit'd public wrappers over the Pallas kernels with jnp-ref fallbacks.

Implementation selection:
  * ``REPRO_KERNEL_IMPL=ref``    — pure-jnp oracles (default on CPU; fully
    differentiable, what the models and the 512-device dry-run lower).
  * ``REPRO_KERNEL_IMPL=pallas`` — Pallas kernels (interpret=True on CPU,
    compiled on TPU).  Forward-only paths.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import ref
from .conv2d import conv2d_pallas
from .flash_attention import flash_attention_pallas
from .rmsnorm import rmsnorm_pallas

__all__ = ["conv2d", "max_pool2d", "flash_attention", "rmsnorm",
           "default_impl"]


def default_impl() -> str:
    impl = os.environ.get("REPRO_KERNEL_IMPL", "")
    if impl:
        return impl
    return "ref" if jax.default_backend() == "cpu" else "pallas"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def conv2d(x, w, padding: str = "SAME", stride: int = 1, impl: str = ""):
    impl = impl or default_impl()
    if impl == "pallas" and stride == 1:
        return conv2d_pallas(x, w, padding=padding, interpret=_interpret())
    return ref.conv2d_ref(x, w, padding=padding, stride=stride)


def max_pool2d(x, window: int = 2, stride: int = 2):
    return ref.max_pool2d_ref(x, window=window, stride=stride)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    impl: str = ""):
    """q: (B,Sq,H,D); k,v: (B,Sk,KH,D) — BSHD layout like the models."""
    impl = impl or default_impl()
    if impl == "pallas":
        out = flash_attention_pallas(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=window,
            softcap=softcap, interpret=_interpret())
        return out.transpose(0, 2, 1, 3)
    return ref.attention_ref(q, k, v, causal=causal,
                             window=window or None, softcap=softcap)


def rmsnorm(x, scale, eps: float = 1e-6, impl: str = ""):
    impl = impl or default_impl()
    if impl == "pallas":
        return rmsnorm_pallas(x, scale, eps=eps, interpret=_interpret())
    return ref.rmsnorm_ref(x, scale, eps=eps)
