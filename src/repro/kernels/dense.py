"""Pallas TPU fused dense layer — the paper's §4.1.2 FC task lists.

The full-connection layer's training step decomposes into per-neuron-block
tasks: the forward (Eq. 19 local response + Eq. 2 bias/activation epilogue
fused into ONE ``pallas_call``) and the backward per-block weight-gradient
tasks G_FC (Eq. 20-21).  The ``pallas_call`` grid cell is one task — a
``(B, Din) x (Din, block)`` matmul over one output-neuron block — and the
block size is the task granularity, chosen by the same Alg. 4.2 cost model
as the conv tile (``core.dag.choose_fc_block``).

Three kernels cover one training step of the layer:

* ``_dense_fwd_kernel`` — matmul + fused bias/activation epilogue.
* ``_dense_dx_kernel`` — input gradient: the same matmul body fed the
  cotangent and the transposed weights, gridded over input-feature blocks.
* ``_dense_dwdb_kernel`` — one G_FC task (§4.1.2): the weight gradient for
  one neuron block (x^T contracted against the cotangent block over the
  batch) with the bias gradient fused into the same cell.

``dense_pallas`` ties them together with ``jax.custom_vjp`` so ``jax.grad``
through the Pallas path trains the FC stack end-to-end (Eq. 19-21) without
falling back to the jnp reference.

Layout: x (B, Din), w (Din, Dout), b (Dout,) — callers with leading batch
dims flatten through ``ops.dense``.  ``interpret=None`` resolves via
``kernels.ops._interpret()`` — interpret mode off TPU, compiled on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

__all__ = ["dense_pallas"]

_ACTIVATIONS = ("none", "relu")


# ----------------------------------------------------------------------
# Kernels
# ----------------------------------------------------------------------
def _dense_fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One FC forward task: (B, Din) x (Din, Nt) + bias + activation.

    x (B, Din); w (Din, Nt); b (1, Nt); o (B, Nt).
    """
    acc = jnp.dot(x_ref[...], w_ref[...],
                  preferred_element_type=jnp.float32)
    acc += b_ref[0, :].astype(jnp.float32)
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _dense_dx_kernel(g_ref, wt_ref, o_ref):
    """Input-gradient task: the same matmul body, no epilogue.

    g (B, Dout); wt (Dout, It) — the transposed weights; o (B, It).
    """
    o_ref[...] = jnp.dot(g_ref[...], wt_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def _dense_dwdb_kernel(x_ref, g_ref, dw_ref, db_ref):
    """One G_FC task (§4.1.2): weight + bias gradient for one neuron block.

    x (B, Din); g (B, Nt); dw (Din, Nt); db (1, Nt).  The cell contracts
    over the batch (Eq. 21's sum over samples) and fuses the Eq. 20 bias
    gradient (cotangent batch-sum) into the same task.
    """
    dw_ref[...] = jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(dw_ref.dtype)
    db_ref[0, :] = jnp.sum(g_ref[...], axis=0,
                           dtype=jnp.float32).astype(db_ref.dtype)


# ----------------------------------------------------------------------
# pallas_call wrappers
# ----------------------------------------------------------------------
def _block_of(features: int, block: int) -> int:
    """Derive a tile over a *different* feature axis than the one the
    caller sized ``block`` for (the dx grid tiles Din with a knob chosen
    for Dout): reuse it when it divides, otherwise run one task.  The
    primary axis validates strictly in ``dense_pallas``.
    """
    if block and features % block == 0:
        return block
    return features


def _forward(x, w, b, *, activation: str, block: int, interpret: bool):
    B, Din = x.shape
    Dout = w.shape[-1]
    nt = block or Dout
    return pl.pallas_call(
        functools.partial(_dense_fwd_kernel, activation=activation),
        grid=(Dout // nt,),
        in_specs=[
            pl.BlockSpec((B, Din), lambda n: (0, 0)),
            pl.BlockSpec((Din, nt), lambda n: (0, n)),
            pl.BlockSpec((1, nt), lambda n: (0, n)),
        ],
        out_specs=pl.BlockSpec((B, nt), lambda n: (0, n)),
        out_shape=jax.ShapeDtypeStruct((B, Dout), x.dtype),
        interpret=interpret,
    )(x, w, b.reshape(1, Dout))


def _backward_dx(g, w, out_dtype, *, block: int, interpret: bool):
    """dL/dx = g @ w^T, gridded over input-feature blocks."""
    B, Dout = g.shape
    Din = w.shape[0]
    it = _block_of(Din, block)
    return pl.pallas_call(
        _dense_dx_kernel,
        grid=(Din // it,),
        in_specs=[
            pl.BlockSpec((B, Dout), lambda n: (0, 0)),
            pl.BlockSpec((Dout, it), lambda n: (0, n)),
        ],
        out_specs=pl.BlockSpec((B, it), lambda n: (0, n)),
        out_shape=jax.ShapeDtypeStruct((B, Din), out_dtype),
        interpret=interpret,
    )(g, w.transpose(1, 0))


def _backward_dwdb(x, g, *, block: int, interpret: bool):
    """dL/dw, dL/db over the per-block G_FC grid (one cell per block)."""
    B, Din = x.shape
    Dout = g.shape[-1]
    nt = block or Dout
    dw, db = pl.pallas_call(
        _dense_dwdb_kernel,
        grid=(Dout // nt,),
        in_specs=[
            pl.BlockSpec((B, Din), lambda n: (0, 0)),
            pl.BlockSpec((B, nt), lambda n: (0, n)),
        ],
        out_specs=[
            pl.BlockSpec((Din, nt), lambda n: (0, n)),
            pl.BlockSpec((1, nt), lambda n: (0, n)),
        ],
        # f32 outputs: gradients round to the param dtypes at the call site
        out_shape=[
            jax.ShapeDtypeStruct((Din, Dout), jnp.float32),
            jax.ShapeDtypeStruct((1, Dout), jnp.float32),
        ],
        interpret=interpret,
    )(x, g)
    return dw, db.reshape(Dout)


# ----------------------------------------------------------------------
# custom_vjp wiring
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dense(cfg, x, w, b):
    activation, block, interpret = cfg
    return _forward(x, w, b, activation=activation, block=block,
                    interpret=interpret)


def _dense_fwd(cfg, x, w, b):
    out = _dense(cfg, x, w, b)
    # The post-activation output doubles as the relu mask (out > 0 iff the
    # pre-activation was > 0), so no pre-activation residual is needed.
    return out, (x, w, b, out)


def _dense_bwd(cfg, residuals, g):
    activation, block, interpret = cfg
    x, w, b, out = residuals
    if activation == "relu":
        g = g * (out > 0).astype(g.dtype)
    dx = _backward_dx(g, w, x.dtype, block=block, interpret=interpret)
    dw, db = _backward_dwdb(x, g, block=block, interpret=interpret)
    return dx, dw.astype(w.dtype), db.astype(b.dtype)


_dense.defvjp(_dense_fwd, _dense_bwd)


# ----------------------------------------------------------------------
# Public entry point
# ----------------------------------------------------------------------
def dense_pallas(x, w, b=None, *, activation: str = "none", block: int = 0,
                 interpret: bool | None = None):
    """Differentiable fused dense: (B, Din) x (Din, Dout) -> (B, Dout).

    ``b`` (Dout,) and ``activation`` fuse the Eq. (2) epilogue into the
    forward kernel; ``jax.grad`` runs the two backward Pallas kernels via
    ``custom_vjp`` (the §4.1.2 per-block G_FC gradient tasks).  ``block``
    is the output-neuron block (0 = all neurons in one task); the grid
    (Dout/block,) is the paper's FC task list.  ``interpret=None``
    resolves via ``kernels.ops._interpret()`` (compiled only on TPU).
    """
    if activation not in _ACTIVATIONS:
        raise ValueError(f"activation must be one of {_ACTIVATIONS}")
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(
            f"dense_pallas takes 2-D x and w, got {x.shape} x {w.shape} "
            "(flatten leading dims through ops.dense)")
    if block and w.shape[-1] % block:
        raise ValueError(
            f"block {block} must divide Dout {w.shape[-1]} "
            "(0 = one task for the whole layer)")
    interpret = resolve_interpret(interpret)
    if b is None:
        b = jnp.zeros((w.shape[-1],), x.dtype)
    cfg = (activation, int(block), bool(interpret))
    return _dense(cfg, x, w, b)
