"""Pallas TPU max-pool2d — the paper's Eq. 15 pooling tasks, differentiable.

TPU adaptation of the paper's per-output-element pooling decomposition:
the ``pallas_call`` grid cell is one image's pooling task list PT_Pool —
Eq. (15) computes every output element as the window max, and the Eq. (18)
backward routes each cotangent element to the argmax position(s) of its
window.

Two kernels cover the layer's training step:

* ``_pool_fwd_kernel`` — Eq. (15): the window max over non-overlapping
  ``window x window`` tiles, computed as ONE reshape + max per image.
* ``_pool_bwd_kernel`` — Eq. (18) error routing: the cotangent flows to the
  positions that achieved the max.  Ties split evenly (mask / tie-count),
  matching ``jax.grad`` of the jnp reference exactly — relu feature maps
  tie often (many exact zeros), so the tie rule is load-bearing for the
  pallas ≡ ref trajectory equivalence, not a corner case.

``max_pool2d_pallas`` ties them together with ``jax.custom_vjp`` so
``jax.grad`` through the Pallas path never falls back to the jnp reference.

Layout: x NHWC.  Non-overlapping pooling only (``stride == window``, the
paper's 2x2 configuration); the ``ops.max_pool2d`` dispatcher applies the
explicit-fallback contract for anything else.  Trailing rows/cols that do
not fill a window are dropped (and receive zero gradient), exactly like
``ref.max_pool2d_ref``.  ``interpret=None`` resolves via
``kernels.ops._interpret()`` — interpret mode off TPU, compiled on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

__all__ = ["max_pool2d_pallas"]


# ----------------------------------------------------------------------
# Kernels
# ----------------------------------------------------------------------
def _pool_fwd_kernel(x_ref, o_ref, *, window: int, Ho: int, Wo: int):
    """One PT_Pool task: all Eq. (15) window maxima for one image.

    x (1, H, W, C); o (1, Ho, Wo, C) with Ho = H // window (trailing
    remainder rows/cols dropped, like the jnp reference).
    """
    k = window
    C = x_ref.shape[-1]
    x = x_ref[0, :Ho * k, :Wo * k, :].reshape(Ho, k, Wo, k, C)
    o_ref[0, :, :, :] = x.max(axis=(1, 3)).astype(o_ref.dtype)


def _pool_bwd_kernel(x_ref, o_ref, g_ref, dx_ref, *, window: int,
                     Ho: int, Wo: int):
    """Eq. (18) error routing for one image: cotangent -> argmax positions.

    x (1, H, W, C); o/g (1, Ho, Wo, C); dx (1, H, W, C).  The saved
    forward output is the argmax oracle: positions equal to the window max
    share the cotangent evenly (ties split 1/count — the jnp/jax rule).
    """
    k = window
    C = x_ref.shape[-1]
    x = x_ref[0, :Ho * k, :Wo * k, :].reshape(Ho, k, Wo, k, C)
    out = o_ref[0, :, :, :][:, None, :, None, :]
    g = g_ref[0, :, :, :][:, None, :, None, :]
    mask = (x == out).astype(jnp.float32)
    counts = mask.sum(axis=(1, 3), keepdims=True)
    routed = g.astype(jnp.float32) * mask / counts
    # dropped remainder rows/cols get zero gradient
    dx_ref[...] = jnp.zeros_like(dx_ref)
    dx_ref[0, :Ho * k, :Wo * k, :] = \
        routed.reshape(Ho * k, Wo * k, C).astype(dx_ref.dtype)


# ----------------------------------------------------------------------
# pallas_call wrappers
# ----------------------------------------------------------------------
def _forward(x, *, window: int, interpret: bool):
    B, H, W, C = x.shape
    Ho, Wo = H // window, W // window
    return pl.pallas_call(
        functools.partial(_pool_fwd_kernel, window=window, Ho=Ho, Wo=Wo),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, H, W, C), lambda bi: (bi, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, Ho, Wo, C), lambda bi: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, C), x.dtype),
        interpret=interpret,
    )(x)


def _backward(x, out, g, *, window: int, interpret: bool):
    B, H, W, C = x.shape
    Ho, Wo = out.shape[1], out.shape[2]
    return pl.pallas_call(
        functools.partial(_pool_bwd_kernel, window=window, Ho=Ho, Wo=Wo),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, H, W, C), lambda bi: (bi, 0, 0, 0)),
            pl.BlockSpec((1, Ho, Wo, C), lambda bi: (bi, 0, 0, 0)),
            pl.BlockSpec((1, Ho, Wo, C), lambda bi: (bi, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, W, C), lambda bi: (bi, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, C), x.dtype),
        interpret=interpret,
    )(x, out, g)


# ----------------------------------------------------------------------
# custom_vjp wiring
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pool(cfg, x):
    window, interpret = cfg
    return _forward(x, window=window, interpret=interpret)


def _pool_fwd(cfg, x):
    out = _pool(cfg, x)
    # the forward output IS the argmax oracle — no index residual needed
    return out, (x, out)


def _pool_bwd(cfg, residuals, g):
    window, interpret = cfg
    x, out = residuals
    return (_backward(x, out, g, window=window, interpret=interpret),)


_pool.defvjp(_pool_fwd, _pool_bwd)


# ----------------------------------------------------------------------
# Public entry point
# ----------------------------------------------------------------------
def max_pool2d_pallas(x, window: int = 2, stride: int = 2, *,
                      interpret: bool | None = None):
    """Differentiable max pooling: (B, H, W, C) -> (B, H//w, W//w, C).

    Non-overlapping windows only (``stride == window``) — the paper's
    pooling configuration; the dispatcher falls back explicitly otherwise.
    ``jax.grad`` runs the Eq. (18) argmax-routing backward kernel via
    ``custom_vjp`` (ties split evenly, matching the jnp oracle).
    ``interpret=None`` resolves via ``kernels.ops._interpret()``.
    """
    if window != stride:
        raise ValueError(
            f"max_pool2d_pallas supports non-overlapping pooling only "
            f"(stride == window), got window={window} stride={stride}")
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    B, H, W, C = x.shape
    if H // window < 1 or W // window < 1:
        raise ValueError(
            f"input {H}x{W} smaller than the {window}x{window} window")
    interpret = resolve_interpret(interpret)
    return _pool((int(window), bool(interpret)), x)
