"""Pallas TPU conv2d — the paper's inner-layer hot spot, now differentiable.

TPU adaptation of the paper's per-output-element task decomposition
(Eq. 13-14): the ``pallas_call`` grid cell *is* the paper's "task" — one
(batch, output-channel-tile) block — and the BlockSpec is the task
granularity.  Instead of scalar element tasks (GPU/CPU-friendly) each task
computes kh*kw shifted (H*W, Cin) x (Cin, Cout_tile) matmuls, the MXU-native
im2col form of Eq. (1).

Three kernels cover one training step of the layer (§4.1):

* ``_conv_fwd_kernel`` — Eq. (1) convolution with the Eq. (2) bias +
  activation epilogue fused in, so the layer forward is ONE ``pallas_call``
  (the paper's PT_Conv task list).
* ``_conv_dx_kernel`` — input gradient: the transposed convolution expressed
  as a VALID correlation of the padded cotangent with the spatially flipped,
  channel-transposed filters, over the same (batch, channel-tile) grid.
* ``_conv_dw_kernel`` — weight gradient: grid cells are the paper's
  per-filter gradient tasks G_Conv (§4.1.2); each cell contracts the padded
  input against the cotangent over (batch, H, W) for one filter tile.

``conv2d_pallas`` ties them together with ``jax.custom_vjp`` so
``jax.grad`` through the Pallas path trains the CNN end-to-end (Eq. 17-23)
without ever falling back to the jnp reference.

Layout: x NHWC, w HWIO, out NHWC.  Stride 1 (the paper's CNNs pool instead
of striding).  ``interpret=None`` resolves via ``kernels.ops._interpret()``
— interpret mode off TPU, compiled on TPU — so callers cannot accidentally
ship interpret-mode kernels to real hardware.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

__all__ = ["conv2d_pallas"]

_ACTIVATIONS = ("none", "relu")


def _same_pads(kh: int, kw: int) -> tuple[int, int]:
    return (kh - 1) // 2, (kw - 1) // 2


# ----------------------------------------------------------------------
# Kernels
# ----------------------------------------------------------------------
def _im2col_accum(in_ref, w_ref, *, kh: int, kw: int, H: int, W: int):
    """The shared task body: kh*kw shifted (H*W, Cin) x (Cin, Ct) matmuls.

    in (1, H+kh-1, W+kw-1, Cin); w (kh, kw, Cin, Ct) -> f32 (H*W, Ct).
    Forward and input-gradient kernels are both this loop — the dx pass
    just feeds the padded cotangent and flipped/transposed filters.
    """
    cin = in_ref.shape[-1]
    ct = w_ref.shape[-1]
    acc = jnp.zeros((H * W, ct), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = in_ref[0, i:i + H, j:j + W, :].reshape(H * W, cin)
            wmat = w_ref[i, j, :, :]
            acc += jnp.dot(patch, wmat, preferred_element_type=jnp.float32)
    return acc


def _conv_fwd_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int,
                     H: int, W: int, activation: str):
    """One PT_Conv task: conv + fused bias/activation epilogue (Eq. 1+2).

    x (1, H+kh-1, W+kw-1, Cin); w (kh,kw,Cin,Ct); b (1,Ct); o (1,H,W,Ct).
    """
    ct = o_ref.shape[-1]
    acc = _im2col_accum(x_ref, w_ref, kh=kh, kw=kw, H=H, W=W)
    acc += b_ref[0, :].astype(jnp.float32)
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[0, :, :, :] = acc.reshape(H, W, ct).astype(o_ref.dtype)


def _conv_dx_kernel(g_ref, w_ref, o_ref, *, kh: int, kw: int,
                    H: int, W: int):
    """Input-gradient task: the same im2col body, no epilogue.

    g (1, H+kh-1, W+kw-1, Cout) — pre-padded cotangent; w here is the
    flipped filter (kh,kw,Cout,Ct_in); o (1,H,W,Ct_in).
    """
    ct = o_ref.shape[-1]
    acc = _im2col_accum(g_ref, w_ref, kh=kh, kw=kw, H=H, W=W)
    o_ref[0, :, :, :] = acc.reshape(H, W, ct).astype(o_ref.dtype)


def _conv_dw_kernel(x_ref, g_ref, o_ref, *, kh: int, kw: int,
                    H: int, W: int):
    """One G_Conv task (§4.1.2): the weight gradient for one filter tile.

    x (Bt, H+kh-1, W+kw-1, Cin); g (Bt, H, W, Ct); o (kh, kw, Cin, Ct).
    The batch is tiled along the *sequential* innermost grid axis so one
    cell only holds a Bt-slice in VMEM; the output block is revisited
    across that axis and accumulated (zeroed at the first batch tile).
    Each visit contracts over (Bt, H, W) with kh*kw (Cin, BtHW) x
    (BtHW, Ct) matmuls.
    """
    bi = pl.program_id(1)
    Bt = x_ref.shape[0]
    cin = x_ref.shape[-1]
    ct = g_ref.shape[-1]

    @pl.when(bi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...].reshape(Bt * H * W, ct)
    for i in range(kh):
        for j in range(kw):
            patch = x_ref[:, i:i + H, j:j + W, :].reshape(Bt * H * W, cin)
            o_ref[i, j, :, :] += jax.lax.dot_general(
                patch, g, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32).astype(o_ref.dtype)


# ----------------------------------------------------------------------
# pallas_call wrappers
# ----------------------------------------------------------------------
def _channel_tile(channels: int, oc_tile: int) -> int:
    """Derive a tile over a *different* channel axis than the one the
    caller sized ``oc_tile`` for (the dx grid tiles Cin with a knob chosen
    for Cout): reuse it when it divides, otherwise fall back to one task
    per image.  The primary axis validates strictly in ``conv2d_pallas``.
    """
    if oc_tile and channels % oc_tile == 0:
        return oc_tile
    return channels


def _forward(x, w, b, *, padding: str, activation: str, oc_tile: int,
             interpret: bool):
    B, H, W, Cin = x.shape
    kh, kw, _, Cout = w.shape
    if padding == "SAME":
        ph, pw = _same_pads(kh, kw)
        xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw),
                         (0, 0)))
    elif padding == "VALID":
        xp = x
        H, W = H - kh + 1, W - kw + 1
    else:
        raise ValueError(padding)
    ct = oc_tile or Cout
    grid = (B, Cout // ct)

    return pl.pallas_call(
        functools.partial(_conv_fwd_kernel, kh=kh, kw=kw, H=H, W=W,
                          activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H + kh - 1, W + kw - 1, Cin),
                         lambda bi, c: (bi, 0, 0, 0)),
            pl.BlockSpec((kh, kw, Cin, ct), lambda bi, c: (0, 0, 0, c)),
            pl.BlockSpec((1, ct), lambda bi, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, H, W, ct), lambda bi, c: (bi, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, Cout), x.dtype),
        interpret=interpret,
    )(xp, w, b.reshape(1, Cout))


def _backward_dx(g, w, x_shape, out_dtype, *, padding: str, oc_tile: int,
                 interpret: bool):
    """dL/dx: VALID correlation of the padded cotangent with flip(w)^T.

    For SAME the cotangent padding mirrors the forward pads
    ((kh-1-ph, ph) vs the forward's (ph, kh-1-ph)); for VALID it is the
    full (kh-1)-halo — both make the output exactly ``x_shape``.
    """
    B, H, W, Cin = x_shape
    kh, kw, _, Cout = w.shape
    wf = w[::-1, ::-1].transpose(0, 1, 3, 2)       # (kh, kw, Cout, Cin)
    if padding == "SAME":
        ph, pw = _same_pads(kh, kw)
        gp = jnp.pad(g, ((0, 0), (kh - 1 - ph, ph), (kw - 1 - pw, pw),
                         (0, 0)))
    else:                                          # VALID
        gp = jnp.pad(g, ((0, 0), (kh - 1, kh - 1), (kw - 1, kw - 1),
                         (0, 0)))
    ct = _channel_tile(Cin, oc_tile)
    grid = (B, Cin // ct)

    return pl.pallas_call(
        functools.partial(_conv_dx_kernel, kh=kh, kw=kw, H=H, W=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H + kh - 1, W + kw - 1, Cout),
                         lambda bi, c: (bi, 0, 0, 0)),
            pl.BlockSpec((kh, kw, Cout, ct), lambda bi, c: (0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, H, W, ct), lambda bi, c: (bi, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, Cin), out_dtype),
        interpret=interpret,
    )(gp, wf)


_DW_BATCH_TILE = 8     # VMEM cap for the dw kernel's per-cell batch slice


def _backward_dw(x, g, w_shape, *, padding: str, oc_tile: int,
                 interpret: bool):
    """dL/dw over the per-filter G_Conv grid.

    Grid (Cout/oc_tile, B/Bt): one output block per filter tile, revisited
    along the sequential batch axis so VMEM holds at most a
    ``_DW_BATCH_TILE``-image slice instead of the whole batch.
    """
    B, H, W, Cin = x.shape
    kh, kw, _, Cout = w_shape
    if padding == "SAME":
        ph, pw = _same_pads(kh, kw)
        xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw),
                         (0, 0)))
        Ho, Wo = H, W
    else:                                          # VALID
        xp = x
        Ho, Wo = H - kh + 1, W - kw + 1
    ct = oc_tile or Cout
    # largest power-of-2 divisor of B up to the cap: the VMEM bound holds
    # for every batch size (odd B degrades to bt=1, never to bt=B)
    bt = math.gcd(B, _DW_BATCH_TILE)

    return pl.pallas_call(
        functools.partial(_conv_dw_kernel, kh=kh, kw=kw, H=Ho, W=Wo),
        grid=(Cout // ct, B // bt),
        in_specs=[
            pl.BlockSpec((bt, Ho + kh - 1, Wo + kw - 1, Cin),
                         lambda c, bi: (bi, 0, 0, 0)),
            pl.BlockSpec((bt, Ho, Wo, ct), lambda c, bi: (bi, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((kh, kw, Cin, ct),
                               lambda c, bi: (0, 0, 0, c)),
        # f32 output: the cross-batch-tile accumulation lives in this
        # buffer, so it must not round through the input dtype
        out_shape=jax.ShapeDtypeStruct((kh, kw, Cin, Cout), jnp.float32),
        interpret=interpret,
    )(xp, g)


# ----------------------------------------------------------------------
# custom_vjp wiring
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _conv2d(cfg, x, w, b):
    padding, activation, oc_tile, interpret = cfg
    return _forward(x, w, b, padding=padding, activation=activation,
                    oc_tile=oc_tile, interpret=interpret)


def _conv2d_fwd(cfg, x, w, b):
    out = _conv2d(cfg, x, w, b)
    # The post-activation output doubles as the relu mask (out > 0 iff the
    # pre-activation was > 0), so no pre-activation residual is needed.
    return out, (x, w, b, out)


def _conv2d_bwd(cfg, residuals, g):
    padding, activation, oc_tile, interpret = cfg
    x, w, b, out = residuals
    if activation == "relu":
        g = g * (out > 0).astype(g.dtype)
    # No f32 input casts: the kernels accumulate in f32 internally
    # (preferred_element_type / f32 dw output), so bf16 models keep bf16
    # memory traffic through the backward pass.
    dx = _backward_dx(g, w, x.shape, x.dtype, padding=padding,
                      oc_tile=oc_tile, interpret=interpret)
    dw = _backward_dw(x, g, w.shape, padding=padding,
                      oc_tile=oc_tile, interpret=interpret).astype(w.dtype)
    db = jnp.sum(g, axis=(0, 1, 2), dtype=jnp.float32).astype(b.dtype)
    return dx, dw, db


_conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)


# ----------------------------------------------------------------------
# Public entry point
# ----------------------------------------------------------------------
def conv2d_pallas(x, w, b=None, *, padding: str = "SAME",
                  activation: str = "none", oc_tile: int = 0,
                  interpret: bool | None = None):
    """Differentiable fused conv2d: (B,H,W,Cin) x (kh,kw,Cin,Cout) -> NHWC.

    ``b`` (Cout,) and ``activation`` fuse the Eq. (2) epilogue into the
    forward kernel; ``jax.grad`` runs the two backward Pallas kernels via
    ``custom_vjp``.  ``oc_tile`` is the output-channel tile (0 = all
    channels in one task); the grid (B, Cout/oc_tile) is the paper's
    parallel task list PT_Conv.  ``interpret=None`` resolves via
    ``kernels.ops._interpret()`` (compiled only on TPU).
    """
    if activation not in _ACTIVATIONS:
        raise ValueError(f"activation must be one of {_ACTIVATIONS}")
    if padding not in ("SAME", "VALID"):
        raise ValueError(padding)
    if oc_tile and w.shape[-1] % oc_tile:
        raise ValueError(
            f"oc_tile {oc_tile} must divide Cout {w.shape[-1]} "
            "(0 = one task per image)")
    interpret = resolve_interpret(interpret)
    if b is None:
        b = jnp.zeros((w.shape[-1],), x.dtype)
    cfg = (padding, activation, int(oc_tile), bool(interpret))
    return _conv2d(cfg, x, w, b)
