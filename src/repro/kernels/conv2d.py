"""Pallas TPU conv2d kernel — the paper's inner-layer hot spot (§4.1.1).

TPU adaptation of the paper's per-output-element task decomposition
(Eq. 13-14): the ``pallas_call`` grid cell *is* the paper's "task" — one
(batch, output-channel-tile) block — and the BlockSpec is the task
granularity.  Instead of scalar element tasks (GPU/CPU-friendly) the kernel
computes each task as kh*kw shifted (H*W, Cin) x (Cin, Cout_tile) matmuls,
which is the MXU-native im2col form of Eq. (1).

Layout: x NHWC (pre-padded by the wrapper), w HWIO, out NHWC.
Stride 1 (the paper's CNNs pool instead of striding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["conv2d_pallas"]


def _conv_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, H: int, W: int):
    """One task: x (1, H+kh-1, W+kw-1, Cin); w (kh,kw,Cin,Ct); o (1,H,W,Ct)."""
    cin = x_ref.shape[-1]
    ct = o_ref.shape[-1]
    acc = jnp.zeros((H * W, ct), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            patch = x_ref[0, i:i + H, j:j + W, :].reshape(H * W, cin)
            wmat = w_ref[i, j, :, :]
            acc += jnp.dot(patch, wmat, preferred_element_type=jnp.float32)
    o_ref[0, :, :, :] = acc.reshape(H, W, ct).astype(o_ref.dtype)


def conv2d_pallas(x, w, *, padding: str = "SAME", oc_tile: int = 0,
                  interpret: bool = True):
    """x: (B,H,W,Cin); w: (kh,kw,Cin,Cout) -> (B,H,W,Cout) (SAME, stride 1).

    ``oc_tile``: output-channel tile (0 = all channels in one task).  The
    grid is (B, Cout/oc_tile) — the paper's parallel task list PT_Conv.
    """
    B, H, W, Cin = x.shape
    kh, kw, _, Cout = w.shape
    if padding == "SAME":
        ph, pw = (kh - 1) // 2, (kw - 1) // 2
        xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw),
                         (0, 0)))
    elif padding == "VALID":
        xp = x
        H, W = H - kh + 1, W - kw + 1
    else:
        raise ValueError(padding)
    oc_tile = oc_tile or Cout
    assert Cout % oc_tile == 0
    grid = (B, Cout // oc_tile)

    out = pl.pallas_call(
        functools.partial(_conv_kernel, kh=kh, kw=kw, H=H, W=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H + kh - 1, W + kw - 1, Cin),
                         lambda b, c: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, Cin, oc_tile),
                         lambda b, c: (0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, H, W, oc_tile),
                               lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, Cout), x.dtype),
        interpret=interpret,
    )(xp, w)
    return out
