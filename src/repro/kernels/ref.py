"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth for the per-kernel allclose sweeps in
``tests/test_kernels.py`` and the differentiable fallback implementation on
CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["conv2d_ref", "max_pool2d_ref", "dense_ref", "attention_ref",
           "rmsnorm_ref"]


def conv2d_ref(x, w, padding: str = "SAME", stride: int = 1):
    """im2col convolution, NHWC x HWIO -> NHWC.  Pure jnp, differentiable."""
    B, H, W, Cin = x.shape
    kh, kw, _, Cout = w.shape
    if padding == "SAME":
        ph, pw = (kh - 1) // 2, (kw - 1) // 2
        ph2, pw2 = kh - 1 - ph, kw - 1 - pw
        x = jnp.pad(x, ((0, 0), (ph, ph2), (pw, pw2), (0, 0)))
    elif padding != "VALID":
        raise ValueError(padding)
    Hp, Wp = x.shape[1], x.shape[2]
    Ho = (Hp - kh) // stride + 1
    Wo = (Wp - kw) // stride + 1
    # gather all kh*kw shifted views: (B, Ho, Wo, kh*kw*Cin)
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(jax.lax.slice(
                x, (0, i, j, 0), (B, i + (Ho - 1) * stride + 1,
                                  j + (Wo - 1) * stride + 1, Cin),
                (1, stride, stride, 1)))
    cols = jnp.concatenate(cols, axis=-1)
    wmat = w.transpose(0, 1, 2, 3).reshape(kh * kw * Cin, Cout)
    out = cols.reshape(B, Ho, Wo, kh * kw * Cin) @ wmat.astype(x.dtype)
    return out


def max_pool2d_ref(x, window: int = 2, stride: int = 2):
    """Non-overlapping window max (window == stride), NHWC.  Differentiable;
    jax.grad splits tied maxima evenly — the contract the Pallas backward
    kernel reproduces."""
    if window != stride:
        raise ValueError(
            f"max_pool2d_ref is non-overlapping only (stride == window), "
            f"got window={window} stride={stride}")
    B, H, W, C = x.shape
    Ho, Wo = H // stride, W // stride
    x = x[:, :Ho * stride, :Wo * stride, :]
    x = x.reshape(B, Ho, stride, Wo, stride, C)
    return x.max(axis=(2, 4))


def dense_ref(x, w, b=None, activation: str = "none"):
    """Fused dense oracle: x @ w (+ b) (+ activation).  Pure jnp,
    differentiable; x may carry leading batch dims."""
    out = x @ w.astype(x.dtype)
    if b is not None:
        out = out + b.astype(out.dtype)
    if activation == "relu":
        out = jax.nn.relu(out)
    elif activation != "none":
        raise ValueError(activation)
    return out


def attention_ref(q, k, v, *, causal=True, window=None, softcap=0.0,
                  scale=None):
    """Naive O(S^2) GQA attention oracle.  q: (B,Sq,H,D); k,v: (B,Sk,KH,D)."""
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale or (1.0 / jnp.sqrt(D))
    qg = q.reshape(B, Sq, KH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kj <= qi + (Sk - Sq)      # align ends when Sq != Sk
    if window:
        mask &= (qi + (Sk - Sq)) - kj < window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
