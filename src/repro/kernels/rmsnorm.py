"""Pallas TPU fused RMSNorm kernel.

Grid over row tiles; each task normalises a (rows_tile, d) block in VMEM —
a fused read-once/write-once pass instead of XLA's separate
square/mean/rsqrt/mul ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import resolve_interpret

__all__ = ["rmsnorm_pallas"]


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


# forward-only for now: the fused backward is the ROADMAP "LM-family
# kernels" item — training falls back to the ref path via ops.rmsnorm
def rmsnorm_pallas(x, scale, eps: float = 1e-6, row_tile: int = 256,  # reprolint: disable=RPL301
                   interpret: bool | None = None):
    """x: (..., d); scale: (d,).  ``interpret=None`` -> ops._interpret()."""
    interpret = resolve_interpret(interpret)
    orig_shape = x.shape
    d = x.shape[-1]
    rows = int(jnp.prod(jnp.array(orig_shape[:-1]))) if len(orig_shape) > 1 else 1
    x2 = x.reshape(rows, d)
    rt = min(row_tile, rows)
    n = -(-rows // rt)
    if n * rt != rows:
        x2 = jnp.pad(x2, ((0, n * rt - rows), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((rt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n * rt, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:rows].reshape(orig_shape)
