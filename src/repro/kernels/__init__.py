"""Pallas kernels for the paper's compute hot spots + the ops dispatch.

Kernel modules (`conv2d`, `flash_attention`, `rmsnorm`) expose raw
``*_pallas`` entry points; ``ops`` wraps them with ref fallbacks and the
``REPRO_KERNEL_IMPL`` switch.  See docs/KERNELS.md.
"""
from __future__ import annotations

__all__ = ["resolve_interpret"]


def resolve_interpret(interpret):
    """Resolve a kernel's ``interpret=None`` default via ``ops._interpret``.

    Kernel entry points must NOT hard-default ``interpret=True`` — that
    silently ships interpret-mode kernels to TPU.  ``None`` means "ask the
    dispatcher": interpret mode everywhere except real TPU silicon.
    """
    if interpret is not None:
        return bool(interpret)
    from repro.kernels import ops      # deferred: ops imports the kernels
    return ops._interpret()
