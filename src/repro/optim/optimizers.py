"""Optimizers (SGD / momentum / AdamW) and LR schedules — pytree-native.

Pure functions: ``init(params) -> state``; ``update(grads, state, params,
lr) -> (updates, state)``; apply with ``apply_updates``.  AdamW is the
dry-run default (its 2x f32 moments are part of the memory roofline).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["sgd", "momentum", "adamw", "apply_updates", "global_norm",
           "clip_by_global_norm", "warmup_cosine", "make_optimizer"]


class Optimizer(NamedTuple):
    init: Callable
    update: Callable        # (grads, state, params, lr) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype),
                                  params, updates)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


# ----------------------------------------------------------------------
def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        return jax.tree_util.tree_map(lambda g: -lr * g, grads), state
    return Optimizer(init, update)


def momentum(beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params, lr):
        new_v = jax.tree_util.tree_map(
            lambda v, g: beta * v + g.astype(jnp.float32), state, grads)
        upd = jax.tree_util.tree_map(lambda v: -lr * v, new_v)
        return upd, new_v
    return Optimizer(init, update)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        def zeros(p):
            return jnp.zeros_like(p, jnp.float32)
        return {
            "mu": jax.tree_util.tree_map(zeros, params),
            "nu": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        upd = jax.tree_util.tree_map(
            lambda m, n, p: -lr * ((m / c1) / (jnp.sqrt(n / c2) + eps)
                                   + weight_decay * p.astype(jnp.float32)),
            mu, nu, params)
        return upd, {"mu": mu, "nu": nu, "count": count}
    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd":
        return sgd()
    if name == "momentum":
        return momentum(**kw)
    if name == "adamw":
        return adamw(**kw)
    raise ValueError(name)


# ----------------------------------------------------------------------
def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        frac = (step - warmup_steps) / jnp.maximum(
            total_steps - warmup_steps, 1)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule
