"""Pytree checkpointing to .npz (flat-key encoding), multi-host-aware.

Simple and dependency-free: flattens the pytree with '/'-joined key paths,
saves host-local numpy arrays.  ``save``/``restore`` round-trip params,
optimizer state and the parameter-server version log.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step"]

_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"#{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    return str(p)


def save(path: str, tree, step: int = 0, metadata: dict | None = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, f"ckpt_{step:08d}.npz"), **flat)
    meta = {"step": step, **(metadata or {})}
    with open(os.path.join(path, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(meta, f)
    return os.path.join(path, f"ckpt_{step:08d}.npz")


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for f in os.listdir(path)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(path: str, like, step: int | None = None):
    """Restore into the structure of ``like`` (a template pytree)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    data = np.load(os.path.join(path, f"ckpt_{step:08d}.npz"))
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    leaves, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        _SEP.join(_path_str(p) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    new_leaves = [
        jax.numpy.asarray(data[key]).astype(leaf.dtype)
        for key, leaf in zip(paths, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step
