"""Crash-safe pytree checkpointing to .npz (flat-key encoding).

Simple and dependency-free: flattens the pytree with '/'-joined key paths,
saves host-local numpy arrays.  ``save``/``restore`` round-trip params,
optimizer state and the parameter-server version log.

Crash-safety contract:

* **Atomic writes** — payload and manifest are written to temp names and
  published with ``os.replace``, manifest first, so a reader never sees a
  truncated ``.npz`` and a visible payload always has its manifest.  A
  process killed mid-save leaves only ``*.tmp`` strays, which
  ``latest_step`` ignores.
* **Validated restores** — the manifest records every key's dtype and
  shape; ``restore`` raises ``CheckpointError`` (not a numpy traceback)
  on a corrupt/partial file, a shape mismatch, or manifest/payload drift.
* **Two checkpoint kinds** — ``kind="ckpt"`` is the plain weight
  checkpoint; ``kind="state"`` is the full resumable training state
  (engine snapshot arrays + JSON scalars: parameter-server version log,
  IDPA allocation state, RNG state, heap clock) that
  ``BPTTrainer.run(hooks=TrainHooks(resume=True))`` restores losslessly.
"""
from __future__ import annotations

import json
import os
import re
import zipfile
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "load_manifest",
           "save_state", "restore_state", "CheckpointError"]

_SEP = "/"
_KINDS = ("ckpt", "state")


class CheckpointError(RuntimeError):
    """A checkpoint file is corrupt, partial, or inconsistent with the
    structure the caller asked to restore into."""


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"#{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    return str(p)


def _check_kind(kind: str) -> str:
    if kind not in _KINDS:
        raise ValueError(f"checkpoint kind={kind!r}: choose one of {_KINDS}")
    return kind


def _payload_name(kind: str, step: int) -> str:
    return f"{kind}_{step:08d}.npz"


def _manifest_path(path: str, kind: str, step: int) -> str:
    return os.path.join(path, f"{kind}_{step:08d}.json")


def _atomic_write_bytes(final: str, write_fn) -> None:
    """Write via a sibling ``.tmp`` + ``os.replace`` so a kill mid-write
    never leaves a truncated file under the published name."""
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)


def save(path: str, tree, step: int = 0, metadata: dict | None = None,
         *, kind: str = "ckpt") -> str:
    """Atomically save ``tree`` as ``<kind>_<step>.npz`` plus a manifest.

    The manifest (``<kind>_<step>.json``) records per-key dtype/shape for
    restore-time validation and carries ``metadata`` verbatim.  It is
    published BEFORE the payload, so a visible ``.npz`` always has its
    manifest; a kill between the two leaves a harmless stray manifest that
    the next save at the same step overwrites.
    """
    _check_kind(kind)
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    manifest = {
        "step": step,
        "format": 1,
        "keys": {k: {"dtype": str(v.dtype), "shape": list(v.shape)}
                 for k, v in flat.items()},
        "metadata": metadata or {},
    }
    payload = json.dumps(manifest).encode()
    _atomic_write_bytes(_manifest_path(path, kind, step),
                        lambda f: f.write(payload))
    final = os.path.join(path, _payload_name(kind, step))
    _atomic_write_bytes(final, lambda f: np.savez(f, **flat))
    return final


def latest_step(path: str, *, kind: str = "ckpt") -> int | None:
    """Largest published step, ignoring strays (``*.tmp``, manifests,
    other kinds, unrelated files)."""
    _check_kind(kind)
    if not os.path.isdir(path):
        return None
    pat = re.compile(rf"{kind}_(\d+)\.npz$")
    steps = [int(m.group(1)) for f in os.listdir(path)
             if (m := pat.fullmatch(f))]
    return max(steps) if steps else None


def load_manifest(path: str, step: int, *, kind: str = "ckpt") -> dict | None:
    """The manifest for ``step``, or None for pre-manifest checkpoints."""
    _check_kind(kind)
    mpath = _manifest_path(path, kind, step)
    if not os.path.exists(mpath):
        return None
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(
            f"checkpoint manifest {mpath} is corrupt: {e}") from e
    # legacy flat format ({"step": ..., **metadata}) has no "keys" entry
    if "keys" not in manifest:
        return {"step": manifest.get("step", step), "format": 0,
                "keys": None, "metadata": manifest}
    return manifest


def restore(path: str, like, step: int | None = None, *,
            kind: str = "ckpt"):
    """Restore into the structure of ``like`` (a template pytree).

    Raises ``FileNotFoundError`` when no checkpoint exists, ``KeyError``
    when the payload lacks keys the template needs, and
    ``CheckpointError`` — with the offending file named — on a corrupt or
    truncated payload, a shape mismatch against the template, or a
    payload whose arrays drifted from the manifest's recorded dtypes.
    Leaves are cast to the template leaf's dtype (so a template built
    from ``jnp.zeros_like`` state restores exactly).
    """
    _check_kind(kind)
    if step is None:
        step = latest_step(path, kind=kind)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    fpath = os.path.join(path, _payload_name(kind, step))
    try:
        data = np.load(fpath)
        files = set(data.files)
    except FileNotFoundError:
        raise
    except (OSError, ValueError, zipfile.BadZipFile, EOFError) as e:
        raise CheckpointError(
            f"checkpoint {fpath} is corrupt or was truncated mid-write "
            f"({e}); delete it and restore an earlier step") from e
    manifest = load_manifest(path, step, kind=kind)
    flat_like = _flatten(like)
    missing = set(flat_like) - files
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    leaves, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        _SEP.join(_path_str(p) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    new_leaves = []
    for key, leaf in zip(paths, leaves, strict=True):
        try:
            arr = data[key]
        except (OSError, ValueError, zipfile.BadZipFile, EOFError,
                KeyError) as e:
            raise CheckpointError(
                f"checkpoint {fpath} key {key!r} is unreadable "
                f"(truncated or corrupt archive member): {e}") from e
        if arr.dtype.kind == "V":
            # ml_dtypes extension dtypes (bfloat16 & friends) come back
            # from .npz as raw void bytes; reinterpret via the manifest's
            # recorded dtype (or the template's, for pre-manifest files)
            rec = manifest["keys"].get(key) if (
                manifest is not None and manifest["keys"] is not None
            ) else None
            try:
                target = np.dtype(rec["dtype"]) if rec \
                    else np.asarray(leaf).dtype
            except TypeError:
                target = arr.dtype      # unknown name: drift check reports
            if arr.dtype.itemsize == target.itemsize:
                arr = arr.view(target)
        if manifest is not None and manifest["keys"] is not None:
            rec = manifest["keys"].get(key)
            if rec is None:
                raise CheckpointError(
                    f"checkpoint {fpath} key {key!r} is absent from its "
                    "manifest — payload and manifest are out of sync")
            if str(arr.dtype) != rec["dtype"] or \
                    list(arr.shape) != rec["shape"]:
                raise CheckpointError(
                    f"checkpoint {fpath} key {key!r} drifted from its "
                    f"manifest: saved {arr.dtype}{list(arr.shape)}, "
                    f"manifest says {rec['dtype']}{rec['shape']}")
        want_shape = tuple(np.shape(leaf))
        if tuple(arr.shape) != want_shape:
            raise CheckpointError(
                f"checkpoint {fpath} key {key!r} has shape "
                f"{tuple(arr.shape)}, template expects {want_shape}")
        new_leaves.append(jax.numpy.asarray(arr).astype(
            np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), step


# ----------------------------------------------------------------------
# train-state checkpoints: engine snapshot arrays + JSON scalar state
# ----------------------------------------------------------------------
def save_state(path: str, arrays, step: int, scalars: dict) -> str:
    """Save one resumable train-state checkpoint (``kind="state"``).

    ``arrays`` is the engine's snapshot pytree (weights, optimizer state,
    per-node locals); ``scalars`` is the JSON-able rest (parameter-server
    version log, IDPA allocation state, RNG state, clocks, heap entries).
    """
    return save(path, arrays, step=step, metadata=scalars, kind="state")


def restore_state(path: str, like, step: int | None = None
                  ) -> tuple[Any, dict, int]:
    """Restore a train-state checkpoint: ``(arrays, scalars, step)``."""
    arrays, step = restore(path, like, step=step, kind="state")
    manifest = load_manifest(path, step, kind="state")
    scalars = manifest["metadata"] if manifest else {}
    return arrays, scalars, step
