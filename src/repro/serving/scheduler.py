"""Request-stream scheduling primitives for the serve engine.

``Request`` is the unit of serving work (a prompt plus a generation
budget, stamped with its arrival time); ``poisson_requests`` synthesises
the millions-of-users scenario at benchmark scale — exponential
inter-arrival gaps and heavy-tailed generation lengths, so arrivals
straddle batch boundaries and a static batch pays the max-of-batch
drain; ``SlotAllocator`` is the free-list over the fixed-capacity
slot-major ``DecodeCache``.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, List

import numpy as np

__all__ = ["Request", "poisson_requests", "SlotAllocator"]


@dataclasses.dataclass
class Request:
    """One serving request.

    ``tokens`` is the (P,) int32 prompt; ``max_new_tokens`` the greedy
    generation budget (0 = the engine plan's default).  ``arrival_ms``
    is on the engine's virtual clock — wall-clock ms when the stream is
    replayed against a ``MeasuredTimer``, cost-model ms under ``ModelTimer``.
    """
    id: int
    arrival_ms: float
    tokens: Any
    max_new_tokens: int = 0


def poisson_requests(n: int, rate_rps: float, *, seed: int = 0,
                     prompt_lens=(8, 12, 16, 24),
                     gen_lens=(4, 8, 16, 48),
                     gen_probs=(0.35, 0.30, 0.25, 0.10),
                     vocab_size: int = 128) -> List[Request]:
    """A Poisson-arrival request stream: exponential gaps at ``rate_rps``
    requests/second, uniform prompt lengths, heavy-tailed generation
    lengths (most requests are short; a 48-token tail makes static
    batching drain at the max of each batch).
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1000.0 / rate_rps, size=n)          # ms
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        p = int(rng.choice(np.asarray(prompt_lens)))
        g = int(rng.choice(np.asarray(gen_lens), p=np.asarray(gen_probs)))
        toks = rng.integers(0, vocab_size, size=(p,), dtype=np.int32)
        reqs.append(Request(id=i, arrival_ms=float(arrivals[i]),
                            tokens=toks, max_new_tokens=g))
    return reqs


class SlotAllocator:
    """Free-list over ``n`` cache slots.  Always hands out the lowest
    free slot so runs are deterministic and evicted slots are provably
    reused (the test_serve invariant)."""

    def __init__(self, n: int):
        self.capacity = n
        self._free = list(range(n))
        heapq.heapify(self._free)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("no free cache slot (capacity "
                               f"{self.capacity}); evict first")
        return heapq.heappop(self._free)

    def free(self, slot: int) -> None:
        if slot in self._free or not 0 <= slot < self.capacity:
            raise ValueError(f"bad free of slot {slot}")
        heapq.heappush(self._free, slot)
