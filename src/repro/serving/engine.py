"""Serve engines: one-call prefill + slot-based continuous batching.

The serving mirror of ``core/engine.py``: interchangeable execution
substrates behind ONE config-resolution point,

| engine                   | batching     | substrate                        |
|--------------------------|--------------|----------------------------------|
| ``ContinuousServeEngine``| ``continuous``| requests join/leave the running  |
|                          |              | decode batch via cache slots     |
| ``StaticServeEngine``    | ``static``   | fixed batches drain at the max   |
|                          |              | of the group before re-forming   |

``resolve_serve_engine(model_cfg, ServeConfig) -> ServePlan`` is the
SINGLE point that inspects the ``batching`` / ``timing`` dispatch fields
(grep-verifiable, like ``resolve_engine``): engines receive a fully
resolved plan — capacity, dtype, and a timer object — and never read the
ServeConfig.

Engines stream: ``run(requests)`` yields one ``ServeEvent`` per
lifecycle step (arrival, prefill, per-token decode, completion) the way
``BPTTrainer.run`` yields ``RoundEvent``s, on a virtual clock advanced
by *measured* call durations (``timing="measured"``) or a deterministic
cost model (``timing="model"`` — reproducible scheduler tests, the PR 7
``duration_source`` idiom).  Prefill is ONE jitted forward over the
whole prompt (``lm.prefill``), not P sequential decode steps; per-step
decode timing is surfaced on every event so the tiled-dense work from
arXiv:1802.04924 has a measurement hook from day one.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.sanitize import sanctioned_scope

from .scheduler import Request, SlotAllocator

__all__ = [
    "ServeConfig", "ServePlan", "ServeEvent", "MeasuredTimer", "ModelTimer",
    "ServeEngine", "ContinuousServeEngine", "StaticServeEngine",
    "resolve_serve_engine", "make_serve_engine",
]


# ----------------------------------------------------------------------
# config & streaming surface
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving knobs.  ``batching`` and ``timing`` are DISPATCH fields:
    only ``resolve_serve_engine`` may inspect them (grep-enforced)."""
    slots: int = 8                 # fixed decode-batch capacity
    max_seq: int = 128             # per-slot cache length (prompt + gen)
    max_new_tokens: int = 16       # default generation budget per request
    batching: str = "continuous"   # continuous | static
    timing: str = "measured"       # measured | model (virtual cost clock)
    cache_dtype: str = "bfloat16"  # bfloat16 | float32 kv payload
    prefill_cost_ms: float = 0.05  # model timing: ms per prompt token
    decode_cost_ms: float = 1.0    # model timing: ms per decode step
    slot_cost_ms: float = 0.0      # model timing: ms per insert/evict

    def __post_init__(self):
        if self.batching not in ("continuous", "static"):
            raise ValueError(f"batching={self.batching!r}: "
                             "'continuous' or 'static'")
        if self.timing not in ("measured", "model"):
            raise ValueError(f"timing={self.timing!r}: 'measured' or 'model'")
        if self.cache_dtype not in ("bfloat16", "float32"):
            raise ValueError(f"cache_dtype={self.cache_dtype!r}: "
                             "'bfloat16' or 'float32'")
        if self.slots < 1:
            raise ValueError("slots must be >= 1")


@dataclasses.dataclass
class ServeEvent:
    """One serving lifecycle step, as seen by a streaming caller.

    ``kind``: ``arrival`` (request entered the stream), ``prefill``
    (whole prompt processed in one call; ``token`` is the first generated
    id, ``ttft_ms`` the time-to-first-token), ``token`` (one decode step;
    ``decode_ms`` is that step's duration), ``complete`` (``tokens`` is
    the full generated sequence, ``latency_ms`` arrival → completion).
    ``t_ms`` is the virtual clock at emission.
    """
    kind: str
    request: int
    t_ms: float
    slot: int = -1
    token: int = -1
    prefill_ms: float = 0.0
    decode_ms: float = 0.0
    ttft_ms: float = 0.0
    latency_ms: float = 0.0
    tokens: Optional[List[int]] = None


# ----------------------------------------------------------------------
# timers: the virtual clock's duration source
# ----------------------------------------------------------------------
class MeasuredTimer:
    """Advance the clock by measured wall time (block_until_ready).

    The block is the measurement, so it routes through the sanitizer's
    ``sanctioned_scope`` — the runtime twin of this class's entry on the
    RPL201/202 ``TIMER_ALLOWLIST``."""
    source = "measured"

    def call(self, kind: str, units: float, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        with sanctioned_scope(f"measured-timer.{kind}"):
            jax.block_until_ready(out)
        return out, (time.perf_counter() - t0) * 1e3


class ModelTimer:
    """Advance the clock by a deterministic cost model — reproducible
    scheduler behaviour regardless of host speed (the PR 7
    ``duration_source='model'`` simulation idiom on the serving side)."""
    source = "model"

    def __init__(self, prefill_cost_ms: float, decode_cost_ms: float,
                 slot_cost_ms: float = 0.0):
        self.prefill_cost_ms = prefill_cost_ms
        self.decode_cost_ms = decode_cost_ms
        self.slot_cost_ms = slot_cost_ms

    def call(self, kind: str, units: float, fn, *args):
        out = fn(*args)
        ms = {"prefill": units * self.prefill_cost_ms,
              "decode": self.decode_cost_ms,
              "slot": self.slot_cost_ms}[kind]
        return out, ms


# ----------------------------------------------------------------------
# the single config-resolution point
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ServePlan:
    """Resolved serving plan.  Fully self-contained: engines read ONLY
    this (capacity, dtype, default budget, timer object) — never the
    ServeConfig, so the dispatch fields stay grep-private to
    ``resolve_serve_engine``."""
    engine_cls: type
    batching: str              # substrate that will execute
    requested: str             # what the config asked for
    timer: Any                 # MeasuredTimer | ModelTimer
    slots: int
    max_seq: int
    max_new_tokens: int
    cache_dtype: Any           # resolved jnp dtype


def resolve_serve_engine(cfg, serve: Optional[ServeConfig] = None
                         ) -> ServePlan:
    """Map (ModelConfig, ServeConfig) to a serving plan.

    Owns every dispatch rule and every actionable error: encoder-decoder
    models are rejected here (their per-request cross-attention memory
    does not fit the slot-major self-attention cache).
    """
    serve = serve if serve is not None else ServeConfig()
    if cfg.arch_type == "encdec":
        raise ValueError(
            "arch_type='encdec' cannot be served by the slot-major decode "
            "cache: each request carries its own cross-attention memory. "
            "Serve a decoder-only arch, or use launch/dryrun for encdec "
            "decode analysis.")
    if serve.max_new_tokens < 1:
        raise ValueError("max_new_tokens must be >= 1")
    if serve.max_seq < 2:
        raise ValueError("max_seq must be >= 2 (prompt + generation)")
    engine_cls = (ContinuousServeEngine if serve.batching == "continuous"
                  else StaticServeEngine)
    timer = (MeasuredTimer() if serve.timing == "measured"
             else ModelTimer(serve.prefill_cost_ms, serve.decode_cost_ms,
                             serve.slot_cost_ms))
    return ServePlan(
        engine_cls=engine_cls,
        batching=serve.batching,
        requested=serve.batching,
        timer=timer,
        slots=serve.slots,
        max_seq=serve.max_seq,
        max_new_tokens=serve.max_new_tokens,
        cache_dtype=(jnp.bfloat16 if serve.cache_dtype == "bfloat16"
                     else jnp.float32),
    )


def make_serve_engine(params, cfg, serve: Optional[ServeConfig] = None
                      ) -> "ServeEngine":
    """Convenience: resolve + instantiate in one call."""
    plan = resolve_serve_engine(cfg, serve)
    return plan.engine_cls(params, cfg, plan)


# ----------------------------------------------------------------------
# engines
# ----------------------------------------------------------------------
class ServeEngine:
    """Base engine: owns the slot-major ``DecodeCache`` and the four
    jitted primitives (prefill / insert / evict / decode).

    ``prefill_traces`` / ``decode_traces`` count actual retraces (the
    counters increment inside the jitted bodies, so they only tick at
    trace time) — the test_serve proof that prefill is ONE jitted call
    per prompt shape, not P sequential steps.
    """

    batching = "base"

    def __init__(self, params, cfg, plan: ServePlan):
        self.params = params
        self.cfg = cfg
        self.plan = plan
        self.cache = lm.init_cache(plan.slots, plan.max_seq, cfg,
                                   dtype=plan.cache_dtype)
        self.prefill_traces = 0
        self.decode_traces = 0
        # donation halves decode cache traffic where the backend supports
        # it; CPU does not and would warn on every call
        donate = (1,) if jax.default_backend() in ("tpu", "gpu") else ()

        def _prefill(p, toks):
            self.prefill_traces += 1
            return lm.prefill(p, toks, cfg, cache_dtype=plan.cache_dtype)

        def _decode(p, cache, toks):
            self.decode_traces += 1
            return lm.decode_step(p, cache, None, toks, cfg)

        self._prefill_jit = jax.jit(_prefill)
        self._decode_jit = jax.jit(_decode, donate_argnums=donate)
        self._insert_jit = jax.jit(lm.cache_insert)
        self._evict_jit = jax.jit(lm.cache_evict)

    # -- jitted primitives behind the plan's timer ---------------------
    def prefill(self, tokens):
        """Whole-prompt forward in ONE jitted call.
        tokens: (B, P) int32 → (last-logits (B,1,V), cache slice, ms)."""
        tokens = jnp.asarray(tokens, jnp.int32)
        if tokens.ndim == 1:
            tokens = tokens[None]
        (logits, sl), ms = self.plan.timer.call(
            "prefill", tokens.shape[1], self._prefill_jit,
            self.params, tokens)
        return logits, sl, ms

    def insert(self, slice_, slot: int, row: int = 0) -> float:
        """Copy ``row`` of a prefill slice into ``slot``; returns ms."""
        self.cache, ms = self.plan.timer.call(
            "slot", 1, self._insert_jit, self.cache, slice_,
            jnp.int32(slot), jnp.int32(row))
        return ms

    def evict(self, slot: int) -> float:
        """Free ``slot`` (length → 0; payload masked out); returns ms."""
        self.cache, ms = self.plan.timer.call(
            "slot", 1, self._evict_jit, self.cache, jnp.int32(slot))
        return ms

    def decode(self, tokens):
        """One decode step for the WHOLE resident batch: every occupied
        slot advances at its own length.  tokens: (slots,) int32 (free
        slots' entries are ignored).  Returns (logits (slots,1,V), ms)."""
        tokens = jnp.asarray(tokens, jnp.int32).reshape(self.plan.slots, 1)
        (logits, self.cache), ms = self.plan.timer.call(
            "decode", 1, self._decode_jit, self.params, self.cache, tokens)
        return logits, ms

    # -- batch helper (the legacy greedy_generate contract) ------------
    def generate(self, prompts, gen: int):
        """Greedy-decode ``gen`` tokens for a (B, P) prompt batch.
        Returns (B, gen) int32.  B must fit the slot capacity."""
        prompts = jnp.asarray(prompts, jnp.int32)
        B, P = prompts.shape
        if B > self.plan.slots:
            raise ValueError(f"batch {B} exceeds slot capacity "
                             f"{self.plan.slots}")
        logits, sl, _ = self.prefill(prompts)
        for b in range(B):
            self.insert(sl, slot=b, row=b)
        tok = np.zeros((self.plan.slots,), np.int32)
        tok[:B] = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        out = [tok[:B].copy()]
        for _ in range(gen - 1):
            logits, _ = self.decode(tok)
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            tok[:B] = nxt[:B]
            out.append(tok[:B].copy())
        for b in range(B):
            self.evict(b)
        return jnp.asarray(np.stack(out, axis=1), jnp.int32)

    # -- request-stream surface ----------------------------------------
    def run(self, requests) -> Iterator[ServeEvent]:
        raise NotImplementedError

    def _budget(self, req: Request) -> int:
        g = req.max_new_tokens or self.plan.max_new_tokens
        p = len(req.tokens)
        if p + g > self.plan.max_seq:
            raise ValueError(
                f"request {req.id}: prompt {p} + max_new_tokens {g} "
                f"exceeds max_seq {self.plan.max_seq}")
        return g

    def _admit(self, req: Request, slot: int, clock: float):
        """Prefill + insert one request into ``slot``.  Returns
        (new_clock, events, state) where state is None when the request
        completed at prefill (budget of exactly one token)."""
        budget = self._budget(req)
        logits, sl, pre_ms = self.prefill(np.asarray(req.tokens)[None])
        clock += pre_ms
        clock += self.insert(sl, slot)
        first = int(jnp.argmax(logits[0, -1]))
        ttft = clock - req.arrival_ms
        events = [ServeEvent(kind="prefill", request=req.id, t_ms=clock,
                             slot=slot, token=first, prefill_ms=pre_ms,
                             ttft_ms=ttft)]
        state = {"req": req, "toks": [first], "budget": budget,
                 "ttft": ttft}
        if budget == 1:
            clock += self.evict(slot)
            events.append(ServeEvent(
                kind="complete", request=req.id, t_ms=clock, slot=slot,
                ttft_ms=ttft, latency_ms=clock - req.arrival_ms,
                tokens=state["toks"]))
            state = None
        return clock, events, state


class ContinuousServeEngine(ServeEngine):
    """Continuous batching: between decode steps, every arrived request
    takes a free slot immediately; completed requests evict their slot
    mid-flight, so the decode batch never drains to re-form."""

    batching = "continuous"

    def run(self, requests) -> Iterator[ServeEvent]:
        stream = iter(requests)
        nxt = next(stream, None)
        free = SlotAllocator(self.plan.slots)
        resident = {}                      # slot -> admission state
        last_tok = np.zeros((self.plan.slots,), np.int32)
        clock = 0.0
        while nxt is not None or resident:
            while (nxt is not None and free.available
                   and nxt.arrival_ms <= clock):
                slot = free.alloc()
                yield ServeEvent(kind="arrival", request=nxt.id,
                                 t_ms=nxt.arrival_ms, slot=slot)
                clock, events, state = self._admit(nxt, slot, clock)
                yield from events
                if state is None:
                    free.free(slot)
                else:
                    resident[slot] = state
                    last_tok[slot] = state["toks"][-1]
                nxt = next(stream, None)
            if not resident:
                if nxt is None:
                    break
                clock = max(clock, nxt.arrival_ms)   # idle: jump to arrival
                continue
            logits, dec_ms = self.decode(last_tok)
            clock += dec_ms
            nxt_tok = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for slot in sorted(resident):
                st = resident[slot]
                tok = int(nxt_tok[slot])
                st["toks"].append(tok)
                last_tok[slot] = tok
                yield ServeEvent(kind="token", request=st["req"].id,
                                 t_ms=clock, slot=slot, token=tok,
                                 decode_ms=dec_ms)
                if len(st["toks"]) >= st["budget"]:
                    clock += self.evict(slot)
                    yield ServeEvent(
                        kind="complete", request=st["req"].id, t_ms=clock,
                        slot=slot, ttft_ms=st["ttft"],
                        latency_ms=clock - st["req"].arrival_ms,
                        tokens=st["toks"])
                    del resident[slot]
                    free.free(slot)


class StaticServeEngine(ServeEngine):
    """Static batching baseline: requests form fixed groups of ``slots``;
    a group only starts once its last member has arrived, and the whole
    group decodes until EVERY member is done (max-of-batch drain) before
    the next group forms — the cost continuous batching removes."""

    batching = "static"

    def run(self, requests) -> Iterator[ServeEvent]:
        reqs = list(requests)
        clock = 0.0
        for start in range(0, len(reqs), self.plan.slots):
            group = reqs[start:start + self.plan.slots]
            for slot, req in enumerate(group):
                yield ServeEvent(kind="arrival", request=req.id,
                                 t_ms=req.arrival_ms, slot=slot)
            clock = max(clock, max(r.arrival_ms for r in group))
            resident = {}
            last_tok = np.zeros((self.plan.slots,), np.int32)
            for slot, req in enumerate(group):
                clock, events, state = self._admit(req, slot, clock)
                yield from events
                if state is not None:
                    resident[slot] = state
                    last_tok[slot] = state["toks"][-1]
            while resident:
                logits, dec_ms = self.decode(last_tok)
                clock += dec_ms
                nxt_tok = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
                for slot in sorted(resident):
                    st = resident[slot]
                    tok = int(nxt_tok[slot])
                    st["toks"].append(tok)
                    last_tok[slot] = tok
                    yield ServeEvent(kind="token", request=st["req"].id,
                                     t_ms=clock, slot=slot, token=tok,
                                     decode_ms=dec_ms)
                    if len(st["toks"]) >= st["budget"]:
                        yield ServeEvent(
                            kind="complete", request=st["req"].id,
                            t_ms=clock, slot=slot, ttft_ms=st["ttft"],
                            latency_ms=clock - st["req"].arrival_ms,
                            tokens=st["toks"])
                        del resident[slot]
            for slot, _ in enumerate(group):
                clock += self.evict(slot)
