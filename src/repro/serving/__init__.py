"""Serving subsystem: one-call prefill, slot-based continuous batching.

Mirrors the training API shape: ``resolve_serve_engine`` is the single
config-resolution point (the serving twin of ``core.engine.resolve_engine``)
and engines stream ``ServeEvent``s the way trainers stream ``RoundEvent``s.
"""
from .engine import (ContinuousServeEngine, MeasuredTimer, ModelTimer,
                     ServeConfig, ServeEngine, ServeEvent, ServePlan,
                     StaticServeEngine, make_serve_engine,
                     resolve_serve_engine)
from .scheduler import Request, SlotAllocator, poisson_requests

__all__ = [
    "ServeConfig", "ServePlan", "ServeEvent", "ServeEngine",
    "ContinuousServeEngine", "StaticServeEngine", "MeasuredTimer",
    "ModelTimer", "resolve_serve_engine", "make_serve_engine",
    "Request", "SlotAllocator", "poisson_requests",
]
