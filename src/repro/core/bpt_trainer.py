"""BPTTrainer — the paper's bi-layered training loop over real JAX steps.

Outer layer: m virtual computing nodes (data-parallel groups).  Each node
pulls the global weights from the ParameterServer, runs ``local_steps``
jitted train steps on its IDPA-assigned data stripe, and pushes back under
SGWU (barrier, Eq. 7) or AGWU (event-ordered, Eq. 9-10).  Node heterogeneity
is emulated with per-node speed factors scaling measured step times into
virtual completion times — the event order (and therefore the staleness
pattern AGWU sees) is exactly the paper's.

With ``TrainConfig.fused_outer`` (the default) the SGWU outer layer is a
single jitted dispatch per round: the m nodes' parameters and optimizer
states live as node-stacked pytrees (leading axis m) and the whole
nodes × local_steps grid runs as ``jax.vmap`` over a ``lax.scan`` — host
dispatch cost is O(1) in m instead of O(m · h), which is precisely the
outer-layer synchronization cost the paper attacks.  AGWU keeps its
event-ordered heap (the ordering IS the algorithm) but pushes through a
pre-jitted, buffer-donating Eq. (10) path.

With ``TrainConfig.device_outer`` the node axis is additionally placed on
a real device mesh (``launch/mesh.py`` `nodes` family): the stacked
pytrees are sharded one node per device, the round runs under
``shard_map`` (node axis = device axis), and the Eq. 7 merge is an
on-device weighted all-reduce inside a device-resident ParameterServer —
the architecture the paper actually describes, with the vmap path as the
transparent single-device fallback.  AGWU under ``device_outer`` keeps
each node's weights on its own device and pushes Eq. 10 deltas.

Inner layer: the jitted step itself — XLA/Pallas task parallelism
(DESIGN.md §3) — plus optional activation remat.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import IDPADataset
from repro.launch.mesh import make_mesh, make_nodes_mesh
from repro.optim.optimizers import (apply_updates, clip_by_global_norm,
                                    make_optimizer, warmup_cosine)

from .gwu import broadcast_tree, tree_sub
from .param_server import ParameterServer
from .types import TrainConfig

__all__ = ["BPTTrainer", "TrainReport"]


@dataclasses.dataclass
class TrainReport:
    strategy: str
    steps: int
    losses: list
    accuracies: list            # (virtual_time, accuracy) pairs
    virtual_makespan: float
    sync_wait: float
    comm_bytes: int
    allocation: np.ndarray
    final_params: object = None
    # which outer-layer execution backend actually ran: "device" (sharded
    # over a real `nodes` mesh), "vmap" (fused single-device emulation),
    # "sequential" (legacy loop), "heap"/"heap-device" (AGWU), "scan"
    # (sync baseline).  The device path falls back to "vmap" when the
    # backend has too few devices — callers can assert on this.
    backend: str = ""

    def summary(self) -> dict:
        return {
            "strategy": self.strategy,
            "backend": self.backend,
            "steps": self.steps,
            "final_loss": round(float(self.losses[-1]), 4) if self.losses else None,
            "final_acc": round(float(self.accuracies[-1][1]), 4)
            if self.accuracies else None,
            "makespan": round(self.virtual_makespan, 3),
            "sync_wait": round(self.sync_wait, 3),
            "comm_MB": round(self.comm_bytes / 2**20, 2),
        }


class BPTTrainer:
    def __init__(self,
                 loss_fn: Callable,                 # (params, batch) -> (loss, aux)
                 init_params,
                 dataset: IDPADataset,
                 train_cfg: TrainConfig,
                 batch_size: int,
                 eval_fn: Optional[Callable] = None,   # (params) -> accuracy
                 speed_factors: Optional[Sequence[float]] = None,
                 accuracy_weighting: str = "normalized"):
        # accuracy_weighting:
        #   "paper"      — Eq. (10) verbatim: scale = gamma * Q.  With small
        #     absolute accuracies early in training this under-applies local
        #     progress (the paper's full-epoch/30-node regime hides it).
        #   "normalized" — beyond-paper fix: Q is divided by its running
        #     mean, so the *relative* contribution weighting the paper wants
        #     is kept while the update magnitude stays ~gamma.
        self.loss_fn = loss_fn
        self.dataset = dataset
        self.tc = train_cfg
        self.batch_size = batch_size
        self.eval_fn = eval_fn
        self.m = train_cfg.outer_nodes
        self.speed = np.asarray(speed_factors if speed_factors is not None
                                else np.ones(self.m), np.float64)
        self.opt = make_optimizer(train_cfg.optimizer)
        self.schedule = warmup_cosine(train_cfg.learning_rate,
                                      train_cfg.warmup_steps,
                                      train_cfg.total_steps)
        self.params0 = init_params
        self.rng = np.random.default_rng(train_cfg.seed)
        self.accuracy_weighting = accuracy_weighting
        self._q_ema = None
        self._eval_vmapped = None    # lazily-built vmap of eval_fn (fused)

        grad_clip = train_cfg.grad_clip

        def step_body(params, opt_state, batch, step):
            (loss, aux), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(params, batch)
            if grad_clip:
                grads, _ = clip_by_global_norm(grads, grad_clip)
            lr = self.schedule(step)
            updates, opt_state = self.opt.update(grads, opt_state, params, lr)
            params = apply_updates(params, updates)
            return params, opt_state, loss

        def node_round(params, opt_state, batches, step):
            """One node's local iteration as a lax.scan over local_steps.

            ``batches`` leaves are (local_steps, B, ...); ``step`` is the
            round index, held constant across the scan exactly like the
            sequential loop held it constant across its local steps.
            """
            def body(carry, batch):
                params, opt_state = carry
                params, opt_state, loss = step_body(
                    params, opt_state, batch, step)
                return (params, opt_state), loss

            (params, opt_state), losses = jax.lax.scan(
                body, (params, opt_state), batches)
            return params, opt_state, losses[-1]

        self._train_step = jax.jit(step_body)
        # single-node round: ONE dispatch per local round (sync baseline)
        self._scan_round = jax.jit(node_round)
        # fused outer layer: nodes × local_steps in ONE dispatch.  The
        # node-stacked params/opt-state buffers are donated — each round
        # consumes the previous round's stack instead of copying it m×.
        self._fused_round = jax.jit(
            jax.vmap(node_round, in_axes=(0, 0, 0, None)),
            donate_argnums=(0, 1))
        self._node_round = node_round
        self._device_rounds = {}     # mesh -> shard_mapped round (lazy)

    def _q_effective(self, q: float) -> float:
        """Relative contribution weight Q (see accuracy_weighting above)."""
        q = max(q, 1e-3)
        if self.accuracy_weighting == "paper":
            return q
        self._q_ema = q if self._q_ema is None else \
            0.9 * self._q_ema + 0.1 * q
        return float(np.clip(q / max(self._q_ema, 1e-3), 0.25, 2.0))

    # ------------------------------------------------------------------
    def _local_round(self, params, opt_state, node: int, step: int):
        """One node's local iteration: ``local_steps`` steps on its stripe."""
        t0 = time.perf_counter()
        loss = None
        for _ in range(self.tc.local_steps):
            batch = self.dataset.node_batch(node, self.batch_size, self.rng)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, loss = self._train_step(
                params, opt_state, batch, jnp.asarray(step, jnp.int32))
        jax.block_until_ready(loss)
        wall = time.perf_counter() - t0
        return params, opt_state, float(loss), wall * self.speed[node]

    def _eval(self, params):
        return float(self.eval_fn(params)) if self.eval_fn else 0.0

    @staticmethod
    def _node_slice(stacked, node: int):
        """Node ``j``'s view of a node-stacked pytree."""
        return jax.tree_util.tree_map(lambda x: x[node], stacked)

    def _eval_nodes(self, stacked) -> list:
        """Per-node accuracies for a node-stacked pytree.

        One vmapped dispatch when ``eval_fn`` is traceable (keeping the
        fused round's O(1)-in-m dispatch property); an eval_fn that fails
        its FIRST vmapped trace/execution (host-side numpy code, python
        control flow) downgrades permanently to the per-node slice loop.
        Failures after a successful first call propagate — they signal a
        real runtime problem, not untraceability.
        """
        if self._eval_vmapped is None:       # first use: probe traceability
            try:
                fn = jax.jit(jax.vmap(self.eval_fn))
                qs = np.asarray(fn(stacked))
                self._eval_vmapped = fn
                return [max(float(q), 1e-3) for q in qs]
            except Exception:
                self._eval_vmapped = False
        if self._eval_vmapped is not False:
            qs = np.asarray(self._eval_vmapped(stacked))
            return [max(float(q), 1e-3) for q in qs]
        return [max(self._eval(self._node_slice(stacked, j)), 1e-3)
                for j in range(self.m)]

    # ------------------------------------------------------------------
    def train(self, rounds: int) -> TrainReport:
        if self.tc.outer_strategy == "sgwu":
            return self._train_sgwu(rounds)
        if self.tc.uneven_batches:
            # only the stacked-round SGWU paths realize the padded+masked
            # stripes; silently training with uniform batches would fake
            # the heterogeneity the flag promises
            raise ValueError(
                "uneven_batches needs outer_strategy='sgwu' (the fused or "
                f"device outer path), not {self.tc.outer_strategy!r}")
        if self.tc.outer_strategy == "agwu":
            return self._train_agwu(rounds)
        return self._train_sync(rounds)

    # -------------------------- plain sync DP --------------------------
    def _train_sync(self, rounds: int) -> TrainReport:
        """Baseline: synchronous data parallelism (one fused scan/round)."""
        params = self.params0
        opt_state = self.opt.init(params)
        losses, accs = [], []
        clock = 0.0
        for r in range(rounds):
            t0 = time.perf_counter()
            batches = [self.dataset.node_batch(0, self.batch_size, self.rng)
                       for _ in range(self.tc.local_steps)]
            stacked = {k: jnp.stack([b[k] for b in batches])
                       for k in batches[0]}
            params, opt_state, loss = self._scan_round(
                params, opt_state, stacked, jnp.asarray(r, jnp.int32))
            jax.block_until_ready(loss)
            clock += (time.perf_counter() - t0) * self.speed[0]
            losses.append(float(loss))
            if self.eval_fn and (r + 1) % 5 == 0:
                accs.append((clock, self._eval(params)))
        return TrainReport("sync", rounds, losses, accs, clock, 0.0, 0,
                           self.dataset.totals, params, backend="scan")

    # ------------------------------ SGWU -------------------------------
    def _train_sgwu(self, rounds: int) -> TrainReport:
        if self.tc.device_outer:
            mesh = self._nodes_mesh()
            if mesh is not None:
                return self._train_sgwu_device(rounds, mesh)
            # too few devices: fall back transparently to the fused vmap
        if self.tc.fused_outer or self.tc.device_outer:
            return self._train_sgwu_fused(rounds)
        return self._train_sgwu_sequential(rounds)

    def _nodes_mesh(self):
        """The `nodes` mesh for the device-sharded outer layer, or None
        when the backend has too few devices (the transparent fallback).
        A ``mesh_name`` whose `nodes` axis mismatches ``outer_nodes`` is a
        config bug, not a capacity problem, and raises."""
        try:
            mesh = make_mesh(self.tc.mesh_name) if self.tc.mesh_name \
                else make_nodes_mesh(self.m)
        except RuntimeError:
            return None
        if "nodes" not in mesh.axis_names or mesh.shape["nodes"] != self.m:
            raise ValueError(
                f"mesh {self.tc.mesh_name!r} needs a `nodes` axis of size "
                f"{self.m}, has axes {dict(mesh.shape)}")
        return mesh

    def _get_device_round(self, mesh):
        """shard_map the fused round over the mesh's `nodes` axis: node
        axis = device axis, so each device runs ITS node's scan on ITS
        resident block of the stacked pytrees — no cross-device traffic
        until the merge all-reduce."""
        if mesh not in self._device_rounds:
            from jax.experimental.shard_map import shard_map
            P = jax.sharding.PartitionSpec
            node_round = self._node_round

            def shard_body(stacked_w, stacked_opt, batches, step):
                # per-device blocks keep a leading node axis (m/devices)
                return jax.vmap(node_round, in_axes=(0, 0, 0, None))(
                    stacked_w, stacked_opt, batches, step)

            sm = shard_map(shard_body, mesh=mesh,
                           in_specs=(P("nodes"), P("nodes"), P("nodes"),
                                     P()),
                           out_specs=(P("nodes"), P("nodes"), P("nodes")))
            self._device_rounds[mesh] = jax.jit(sm, donate_argnums=(0, 1))
        return self._device_rounds[mesh]

    def _train_sgwu_device(self, rounds: int, mesh) -> TrainReport:
        """Device-sharded outer layer: the paper's m physical nodes.

        Identical round structure to the fused path (the shared
        ``_run_stacked_rounds`` loop), but the node-stacked pytrees are
        placed with ``NamedSharding`` over the mesh's `nodes` axis (node
        j resident on device j), the round runs under ``shard_map``, and
        the Eq. 7 merge is an on-device weighted all-reduce inside the
        device-resident ParameterServer — the global weights never
        funnel through host or a single device.
        """
        server = ParameterServer(self.params0, self.m, mesh=mesh)
        node_sharding = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("nodes"))
        stacked_opt = jax.device_put(
            broadcast_tree(self.opt.init(self.params0), self.m),
            node_sharding)
        return self._run_stacked_rounds(
            rounds, server, stacked_opt, self._get_device_round(mesh),
            node_sharding, backend="device")

    def _train_sgwu_fused(self, rounds: int) -> TrainReport:
        """Fused outer layer: the m nodes' round is ONE jitted dispatch.

        Node-stacked params/opt-states flow ``pull_all_stacked`` →
        ``_fused_round`` (vmap over nodes, scan over local steps, stacked
        buffers donated) → ``push_sgwu_stacked`` (jitted Eq. 7 merge on the
        stack, donated).
        """
        server = ParameterServer(self.params0, self.m)
        stacked_opt = broadcast_tree(self.opt.init(self.params0), self.m)
        return self._run_stacked_rounds(
            rounds, server, stacked_opt, self._fused_round, None,
            backend="vmap")

    def _run_stacked_rounds(self, rounds: int, server: ParameterServer,
                            stacked_opt, round_fn, batch_sharding,
                            backend: str) -> TrainReport:
        """The stacked SGWU round loop shared by the fused-vmap and
        device-sharded backends — they differ only in the server mode,
        the round callable and the batch placement, so the Eq. 7/8
        bookkeeping lives exactly once.

        Per-node virtual durations are an equal share of the measured
        round wall scaled by the node speed factors — the heterogeneity
        emulation the sequential loop derived from per-node measurement.
        """
        losses, accs = [], []
        clock, sync_wait = 0.0, 0.0
        for r in range(rounds):
            stacked_w, _ = server.pull_all_stacked()
            t0 = time.perf_counter()
            batches = self.dataset.stacked_round_batches(
                self.batch_size, self.tc.local_steps, self.rng,
                uneven=self.tc.uneven_batches)
            if batch_sharding is not None:
                batches = jax.device_put(batches, batch_sharding)
            stacked_w, stacked_opt, node_losses = round_fn(
                stacked_w, stacked_opt, batches, jnp.asarray(r, jnp.int32))
            node_losses = np.asarray(jax.block_until_ready(node_losses))
            wall = time.perf_counter() - t0
            durs = (wall / self.m) * self.speed
            clock += durs.max()
            sync_wait += float((durs.max() - durs).sum())      # Eq. (8)
            if self.eval_fn:
                qs = self._eval_nodes(stacked_w)
            else:
                qs = [1.0] * self.m          # SGWU normalises in Eq. 7
            server.push_sgwu_stacked(stacked_w, qs, virtual_time=clock)
            losses.append(float(node_losses.mean()))
            self.dataset.report_durations(durs)
            if self.eval_fn:
                accs.append((clock, self._eval(server.global_weights)))
        return TrainReport("sgwu", rounds, losses, accs, clock, sync_wait,
                           server.comm_bytes, self.dataset.totals,
                           server.global_weights, backend=backend)

    def _train_sgwu_sequential(self, rounds: int) -> TrainReport:
        """Legacy emulation: one jitted step per node per local step.

        Kept as the reference the fused path is regression-tested against
        (and the baseline ``benchmarks/outer_loop.py`` measures)."""
        if self.tc.uneven_batches:
            raise ValueError(
                "uneven_batches needs the fused or device outer path")
        server = ParameterServer(self.params0, self.m)
        opt_states = [self.opt.init(self.params0) for _ in range(self.m)]
        losses, accs = [], []
        clock, sync_wait = 0.0, 0.0
        for r in range(rounds):
            subs, durs = [], np.zeros(self.m)
            node_losses = np.zeros(self.m)
            for j in range(self.m):
                w, _ = server.pull(j)
                w2, opt_states[j], loss, dur = self._local_round(
                    w, opt_states[j], j, r)
                q = self._eval(w2) if self.eval_fn else 1.0
                subs.append((j, w2, max(q, 1e-3)))  # SGWU normalises in Eq. 7
                durs[j] = dur
                node_losses[j] = loss
            clock += durs.max()
            sync_wait += float((durs.max() - durs).sum())      # Eq. (8)
            server.push_sgwu(subs, virtual_time=clock)
            losses.append(float(node_losses.mean()))
            self.dataset.report_durations(durs)
            if self.eval_fn:
                accs.append((clock, self._eval(server.global_weights)))
        return TrainReport("sgwu", rounds, losses, accs, clock, sync_wait,
                           server.comm_bytes, self.dataset.totals,
                           server.global_weights, backend="sequential")

    # ------------------------------ AGWU -------------------------------
    def _train_agwu(self, rounds: int) -> TrainReport:
        """AGWU keeps its event-ordered heap (the ordering IS the
        algorithm).  With ``device_outer`` and enough devices, each node's
        weights/opt-state live on its own device; a push computes the
        Eq. 10 delta W_j(k) - W(k) on the node's device and ships ONLY
        the delta to the server (``push_agwu_delta``)."""
        server = ParameterServer(self.params0, self.m)
        devices = jax.devices()
        device_nodes = self.tc.device_outer and len(devices) >= self.m
        if not device_nodes:
            server.warmup_agwu()   # compile the donated Eq. 10 push up front
        opt_states = [self.opt.init(self.params0) for _ in range(self.m)]
        losses, accs = [], []
        heap: list[tuple[float, int, int]] = []     # (vtime, node, round)
        local, base_local = {}, {}
        rounds_done = np.zeros(self.m, np.int64)
        node_durs = np.ones(self.m)

        def pull_to_node(j: int):
            w, _ = server.pull(j)
            if device_nodes:
                w = jax.device_put(w, devices[j])
                base_local[j] = w          # W(k) snapshot, node-resident
            return w

        for j in range(self.m):
            if device_nodes:
                opt_states[j] = jax.device_put(opt_states[j], devices[j])
            local[j] = pull_to_node(j)
            heapq.heappush(heap, (0.0, j, 0))

        clock = 0.0
        while heap:
            vt, j, r = heapq.heappop(heap)
            w2, opt_states[j], loss, dur = self._local_round(
                local[j], opt_states[j], j, r)
            node_durs[j] = dur
            clock = vt + dur
            q = self._eval(w2) if self.eval_fn else 1.0
            if device_nodes:
                delta = tree_sub(w2, base_local[j])   # on node j's device
                server.push_agwu_delta(j, delta, self._q_effective(q),
                                       virtual_time=clock)
            else:
                server.push_agwu(j, w2, self._q_effective(q),
                                 virtual_time=clock,
                                 donate=True)  # w2 is dead after the push
            losses.append(loss)
            rounds_done[j] += 1
            if int(rounds_done.min()) >= self.dataset.part.current_batch:
                self.dataset.report_durations(node_durs * self.dataset.totals
                                              / max(self.batch_size, 1))
            if self.eval_fn and len(losses) % self.m == 0:
                accs.append((clock, self._eval(server.global_weights)))
            if rounds_done[j] < rounds:
                local[j] = pull_to_node(j)
                heapq.heappush(heap, (clock, j, int(rounds_done[j])))
        return TrainReport("agwu", int(rounds_done.sum()), losses, accs,
                           clock, 0.0, server.comm_bytes,
                           self.dataset.totals, server.global_weights,
                           backend="heap-device" if device_nodes else "heap")
