"""BPTTrainer — the paper's bi-layered training loop over real JAX steps.

Outer layer: m virtual computing nodes (data-parallel groups).  Each node
pulls the global weights from the ParameterServer, runs ``local_steps``
jitted train steps on its IDPA-assigned data stripe, and pushes back under
SGWU (barrier, Eq. 7) or AGWU (event-ordered, Eq. 9-10).  Node heterogeneity
is emulated with per-node speed factors scaling measured step times into
virtual completion times — the event order (and therefore the staleness
pattern AGWU sees) is exactly the paper's.

The outer layer's execution substrates are pluggable engines
(``repro.core.engine``): the sync scan baseline, the legacy sequential
loop, the fused vmap(nodes) x scan(local_steps) dispatch, the
shard_map round on a real `nodes` device mesh, and the AGWU event heap
(host-server or node-pinned delta-push variants).
``engine.resolve_engine`` is the single point that maps a TrainConfig to
an engine — it owns every flag-combination rule and the transparent
device-count fallback, which is recorded in the ``EnginePlan`` and
surfaced on ``TrainReport.fallback``.

Two entry points:

- ``run(rounds, hooks)`` — a generator yielding one ``RoundEvent`` per
  merge (per round for SGWU/sync, per push for AGWU) so callers stream
  losses, evaluate on their own cadence, checkpoint mid-run and
  early-stop.  ``TrainHooks`` supplies the eval / checkpoint / callback
  cadences.
- ``train(rounds, hooks)`` — drains ``run`` into a ``TrainReport``; the
  historical API every test and driver keeps using.

Inner layer: the jitted step itself — XLA/Pallas task parallelism
(DESIGN.md §3) — plus optional activation remat.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import checkpoint
from repro.data.pipeline import IDPADataset
from repro.sanitize import sanctioned_scope, sanctioned_sync
from repro.optim.optimizers import (apply_updates, clip_by_global_norm,
                                    make_optimizer, warmup_cosine)

from .engine import RoundEvent, TrainHooks, resolve_engine
from .types import TrainConfig

__all__ = ["BPTTrainer", "TrainReport", "TrainHooks", "RoundEvent"]


@dataclasses.dataclass
class TrainReport:
    strategy: str
    steps: int
    losses: list
    accuracies: list            # (virtual_time, accuracy) pairs
    virtual_makespan: float
    sync_wait: float
    comm_bytes: int
    allocation: np.ndarray
    final_params: object = None
    # which outer-layer execution backend actually ran: "device" (sharded
    # over a real `nodes` mesh), "vmap" (fused single-device emulation),
    # "sequential" (legacy loop), "heap"/"heap-device" (AGWU), "scan"
    # (sync baseline).  The device path falls back to "vmap" when the
    # backend has too few devices — callers can assert on this.
    backend: str = ""
    # non-empty when the executed backend differs from the requested one
    # (the EnginePlan's recorded device-count fallback reason)
    fallback: str = ""
    # global index just past the last event (= its round + 1); differs
    # from ``steps`` when the run resumed from a state checkpoint, where
    # ``steps`` counts only the events this process produced
    last_event: int = 0

    def summary(self) -> dict:
        out = {
            "strategy": self.strategy,
            "backend": self.backend,
            "steps": self.steps,
            "final_loss": round(float(self.losses[-1]), 4) if self.losses else None,
            "final_acc": round(float(self.accuracies[-1][1]), 4)
            if self.accuracies else None,
            "makespan": round(self.virtual_makespan, 3),
            "sync_wait": round(self.sync_wait, 3),
            "comm_MB": round(self.comm_bytes / 2**20, 2),
        }
        if self.fallback:
            out["fallback"] = self.fallback
        return out


class BPTTrainer:
    def __init__(self,
                 loss_fn: Callable,                 # (params, batch) -> (loss, aux)
                 init_params,
                 dataset: IDPADataset,
                 train_cfg: TrainConfig,
                 batch_size: int,
                 eval_fn: Optional[Callable] = None,   # (params) -> accuracy
                 speed_factors: Optional[Sequence[float]] = None,
                 accuracy_weighting: str = "normalized",
                 model_cfg=None,
                 plan_family: str = "",
                 fault_schedule=None):
        # accuracy_weighting:
        #   "paper"      — Eq. (10) verbatim: scale = gamma * Q.  With small
        #     absolute accuracies early in training this under-applies local
        #     progress (the paper's full-epoch/30-node regime hides it).
        #   "normalized" — beyond-paper fix: Q is divided by its running
        #     mean, so the *relative* contribution weighting the paper wants
        #     is kept while the update magnitude stays ~gamma.
        self.loss_fn = loss_fn
        self.dataset = dataset
        self.tc = train_cfg
        self.batch_size = batch_size
        self.eval_fn = eval_fn
        # optional model config (e.g. CNNConfig): lets the 2-D hybrid-mesh
        # engine plan per-layer parallelization (core.planner); without it
        # a 2-D mesh runs the generic batch-family plan.  ``plan_family``
        # forces a planner family ("batch"/"channel", tests & search);
        # "" lets the cost model pick.
        self.model_cfg = model_cfg
        self.plan_family = plan_family
        self.m = train_cfg.outer_nodes
        # optional FaultSchedule (core.faults): node churn the engines
        # replay — fail/rejoin/slow transitions keyed on event indices
        self.faults = fault_schedule
        if fault_schedule is not None and not fault_schedule.empty:
            fault_schedule.validate_nodes(self.m)
        self.speed = np.asarray(speed_factors if speed_factors is not None
                                else np.ones(self.m), np.float64)
        self.opt = make_optimizer(train_cfg.optimizer)
        self.schedule = warmup_cosine(train_cfg.learning_rate,
                                      train_cfg.warmup_steps,
                                      train_cfg.total_steps)
        self.params0 = init_params
        self.rng = np.random.default_rng(train_cfg.seed)
        self.accuracy_weighting = accuracy_weighting
        self._q_ema = None
        self._eval_vmapped = None    # lazily-built vmap of eval_fn (fused)
        self.last_plan = None        # EnginePlan of the most recent run()
        self.last_engine = None      # engine instance of the most recent run()

        node_round = self._make_node_round()
        self._train_step = jax.jit(self._make_step_body())
        # single-node round: ONE dispatch per local round (sync baseline)
        self._scan_round = jax.jit(node_round)
        # fused outer layer: nodes × local_steps in ONE dispatch.  The
        # node-stacked params/opt-state buffers are donated — each round
        # consumes the previous round's stack instead of copying it m×.
        self._fused_round = jax.jit(
            jax.vmap(node_round, in_axes=(0, 0, 0, None)),
            donate_argnums=(0, 1))
        self._node_round = node_round
        self._device_rounds = {}     # (mesh, plan) -> shard_mapped round

    def _make_step_body(self, combine=None):
        """One optimizer step.  ``combine`` (model-axis rounds) recombines
        the per-shard loss/grads BEFORE clipping, so the clip sees the
        same global norm the unsharded paths clip."""
        grad_clip = self.tc.grad_clip

        def step_body(params, opt_state, batch, step):
            (loss, aux), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(params, batch)
            if combine is not None:
                loss, grads = combine(loss, grads, batch)
            if grad_clip:
                grads, _ = clip_by_global_norm(grads, grad_clip)
            lr = self.schedule(step)
            updates, opt_state = self.opt.update(grads, opt_state, params, lr)
            params = apply_updates(params, updates)
            return params, opt_state, loss

        return step_body

    def _make_node_round(self, combine=None):
        """One node's local iteration as a lax.scan over local_steps.

        ``batches`` leaves are (local_steps, B, ...); ``step`` is the
        round index, held constant across the scan exactly like the
        sequential loop held it constant across its local steps.
        """
        step_body = self._make_step_body(combine)

        def node_round(params, opt_state, batches, step):
            def body(carry, batch):
                params, opt_state = carry
                params, opt_state, loss = step_body(
                    params, opt_state, batch, step)
                return (params, opt_state), loss

            (params, opt_state), losses = jax.lax.scan(
                body, (params, opt_state), batches)
            return params, opt_state, losses[-1]

        return node_round

    def _q_effective(self, q: float) -> float:
        """Relative contribution weight Q (see accuracy_weighting above)."""
        q = max(q, 1e-3)
        if self.accuracy_weighting == "paper":
            return q
        self._q_ema = q if self._q_ema is None else \
            0.9 * self._q_ema + 0.1 * q
        return float(np.clip(q / max(self._q_ema, 1e-3), 0.25, 2.0))

    # ------------------------------------------------------------------
    def _local_round(self, params, opt_state, node: int, step: int):
        """One node's local iteration: ``local_steps`` steps on its stripe."""
        t0 = time.perf_counter()
        loss = None
        for _ in range(self.tc.local_steps):
            batch = self.dataset.node_batch(node, self.batch_size, self.rng)
            # one explicit placement for batch + step scalar: the train
            # step dispatch never uploads implicitly (transfer-guard clean)
            batch, step_dev = jax.device_put((batch, np.int32(step)))
            params, opt_state, loss = self._train_step(
                params, opt_state, batch, step_dev)
        # Eq. 8 measurement boundary — a sanctioned sync, not a hidden one
        loss = float(sanctioned_sync(loss, "local-round.loss"))
        wall = time.perf_counter() - t0
        return params, opt_state, loss, wall * self.speed[node]

    def _eval(self, params):
        # accuracy evals PULL by design (the scalar feeds Eq. 7/10
        # weighting), and eval_fns are caller-supplied host code — the
        # whole call is a sanctioned scope under the transfer sanitizer
        if not self.eval_fn:
            return 0.0
        with sanctioned_scope("eval"):
            return float(self.eval_fn(params))

    @staticmethod
    def _node_slice(stacked, node: int):
        """Node ``j``'s view of a node-stacked pytree."""
        return jax.tree_util.tree_map(lambda x: x[node], stacked)

    def _eval_nodes(self, stacked) -> list:
        """Per-node accuracies for a node-stacked pytree.

        One vmapped dispatch when ``eval_fn`` is traceable (keeping the
        fused round's O(1)-in-m dispatch property); an eval_fn that fails
        its FIRST vmapped trace/execution (host-side numpy code, python
        control flow) downgrades permanently to the per-node slice loop.
        Failures after a successful first call propagate — they signal a
        real runtime problem, not untraceability.
        """
        if self._eval_vmapped is None:       # first use: probe traceability
            try:
                fn = jax.jit(jax.vmap(self.eval_fn))
                qs = sanctioned_sync(fn(stacked), "eval-nodes")
                self._eval_vmapped = fn
                return [max(float(q), 1e-3) for q in qs]
            except Exception:
                self._eval_vmapped = False
        if self._eval_vmapped is not False:
            qs = sanctioned_sync(self._eval_vmapped(stacked), "eval-nodes")
            return [max(float(q), 1e-3) for q in qs]
        return [max(self._eval(self._node_slice(stacked, j)), 1e-3)
                for j in range(self.m)]

    def _get_device_round(self, mesh, netplan=None):
        """shard_map the fused round over the mesh's `nodes` axis: node
        axis = device axis, so each device runs ITS node's scan on ITS
        resident block of the stacked pytrees — no cross-device traffic
        until the merge all-reduce.

        On a 2-D ``(nodes, model)`` mesh the round executes ``netplan``
        (``core.planner.NetworkPlan``): batches are placed with the
        plan's ``batch_spec`` (batch family: the per-node stripe splits
        over ``model`` too), and a batch-family plan recombines the
        per-shard loss/grads with the exact sample-count-weighted psum
        over ``model`` — restricted to the ``model`` axis only, so the
        Eq. 7 merge psum stays a pure ``nodes`` collective.  Cached per
        (mesh, plan) so repeated runs reuse the compiled dispatch."""
        key = (mesh, netplan)
        if key not in self._device_rounds:
            from jax.experimental.shard_map import shard_map
            P = jax.sharding.PartitionSpec
            node_round = self._node_round
            batch_spec = P("nodes")
            if netplan is not None and netplan.model > 1:
                from repro.core import planner
                batch_spec = netplan.batch_spec
                if netplan.combine_grads:
                    node_round = self._make_node_round(
                        planner.grad_combine(netplan))

            def shard_body(stacked_w, stacked_opt, batches, step):
                # per-device blocks keep a leading node axis (m/devices)
                return jax.vmap(node_round, in_axes=(0, 0, 0, None))(
                    stacked_w, stacked_opt, batches, step)

            # check_rep=False: pallas_call carries no replication rule
            # (the shard_map checker rejects any kernel-impl round), and
            # the planned 2-D body's custom-VJP collectives already
            # encode the model-axis replication the checker would try to
            # infer.  The equivalence suite gates the semantics instead.
            sm = shard_map(shard_body, mesh=mesh,
                           in_specs=(P("nodes"), P("nodes"), batch_spec,
                                     P()),
                           out_specs=(P("nodes"), P("nodes"), P("nodes")),
                           check_rep=False)
            self._device_rounds[key] = jax.jit(sm, donate_argnums=(0, 1))
        return self._device_rounds[key]

    # ------------------------------------------------------------------
    def run(self, rounds: int,
            hooks: Optional[TrainHooks] = None) -> Iterator[RoundEvent]:
        """Stream the outer layer: one ``RoundEvent`` per merge.

        Resolves the execution engine (``engine.resolve_engine``), then
        yields each merge event — round index, per-node losses, virtual
        clock, cumulative sync-wait and comm-bytes, and the pull-able
        post-merge global weights.  Callers evaluate / checkpoint /
        early-stop at will; breaking out of the iterator stops training.

        ``hooks`` layers cadences on the stream: accuracy evals every
        ``eval_every`` events (0 keeps the engine's historical default),
        ``checkpoint_every`` saves ``event.params`` into
        ``checkpoint_dir`` via ``repro.checkpointing`` — plus, for
        resumable engines, a ``kind="state"`` checkpoint carrying the
        engine snapshot, parameter-server log, IDPA allocation state and
        host RNG state — and ``on_round`` observes every event before it
        is yielded.  ``hooks.resume=True`` restores the latest state
        checkpoint before the first event, so a killed run relaunched
        with the same config continues losslessly (and a first launch
        with ``resume=True`` simply starts from scratch).

        A generator: config errors raise at the first ``next()``.
        """
        hooks = hooks or TrainHooks()
        plan = resolve_engine(self.tc)
        self.last_plan = plan
        engine = plan.engine_cls(self, plan)
        self.last_engine = engine
        eval_every = hooks.eval_every or engine.default_eval_every
        state = engine.setup(rounds)
        start = 0
        if hooks.resume and hooks.checkpoint_dir:
            start = self._restore_run(engine, state, hooks.checkpoint_dir)
        for ev in engine.events(rounds, start=start, state=state):
            n = ev.round + 1
            if self.eval_fn and n % eval_every == 0:
                ev.accuracy = self._eval(ev.params)
            if hooks.checkpoint_every and hooks.checkpoint_dir \
                    and n % hooks.checkpoint_every == 0:
                checkpoint.save(hooks.checkpoint_dir, ev.params, step=n)
                self._save_run_state(engine, state, hooks.checkpoint_dir, n)
            if hooks.on_round:
                hooks.on_round(ev)
            yield ev

    def _save_run_state(self, engine, state, ckpt_dir: str, n: int) -> None:
        """Write the resumable train state (``kind="state"``) at event n."""
        snap = engine.snapshot(state)
        if snap is None:
            return                       # engine is not resumable
        arrays, scalars = snap
        scalars["trainer"] = {
            "next_event": n,
            "rng": self.rng.bit_generator.state,
            "dataset": self.dataset.state_dict(),
            "q_ema": self._q_ema,
        }
        checkpoint.save_state(ckpt_dir, arrays, n, scalars)

    def _restore_run(self, engine, state, ckpt_dir: str) -> int:
        """Restore the latest state checkpoint into ``state``; returns the
        event index to resume from (0 when no state checkpoint exists)."""
        step = checkpoint.latest_step(ckpt_dir, kind="state")
        if step is None:
            return 0
        snap = engine.snapshot(state)
        if snap is None:
            raise ValueError(
                f"{type(engine).__name__} does not support resumption but "
                f"{ckpt_dir} holds a state checkpoint")
        arrays_like, _ = snap
        arrays, scalars, _ = checkpoint.restore_state(
            ckpt_dir, arrays_like, step)
        engine.restore_snapshot(state, arrays, scalars)
        tr = scalars["trainer"]
        self.rng.bit_generator.state = tr["rng"]
        self.dataset.load_state_dict(tr["dataset"])
        self._q_ema = tr["q_ema"]
        return int(tr["next_event"])

    def train(self, rounds: int,
              hooks: Optional[TrainHooks] = None) -> TrainReport:
        """Drain ``run`` into a ``TrainReport`` (the historical API)."""
        losses, accs = [], []
        last = None
        for ev in self.run(rounds, hooks):
            losses.append(ev.loss)
            if ev.accuracy is not None:
                accs.append((ev.virtual_clock, ev.accuracy))
            last = ev
        plan = self.last_plan
        return TrainReport(
            plan.strategy, len(losses), losses, accs,
            last.virtual_clock if last else 0.0,
            last.sync_wait if last else 0.0,
            last.comm_bytes if last else 0,
            self.dataset.totals,
            last.params if last is not None else self.params0,
            backend=plan.backend, fallback=plan.fallback,
            last_event=last.round + 1 if last is not None else 0)
