"""BPTTrainer — the paper's bi-layered training loop over real JAX steps.

Outer layer: m virtual computing nodes (data-parallel groups).  Each node
pulls the global weights from the ParameterServer, runs ``local_steps``
jitted train steps on its IDPA-assigned data stripe, and pushes back under
SGWU (barrier, Eq. 7) or AGWU (event-ordered, Eq. 9-10).  Node heterogeneity
is emulated with per-node speed factors scaling measured step times into
virtual completion times — the event order (and therefore the staleness
pattern AGWU sees) is exactly the paper's.

Inner layer: the jitted step itself — XLA/Pallas task parallelism
(DESIGN.md §3) — plus optional activation remat.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import IDPADataset
from repro.optim.optimizers import (apply_updates, clip_by_global_norm,
                                    make_optimizer, warmup_cosine)

from .param_server import ParameterServer
from .types import TrainConfig

__all__ = ["BPTTrainer", "TrainReport"]


@dataclasses.dataclass
class TrainReport:
    strategy: str
    steps: int
    losses: list
    accuracies: list            # (virtual_time, accuracy) pairs
    virtual_makespan: float
    sync_wait: float
    comm_bytes: int
    allocation: np.ndarray
    final_params: object = None

    def summary(self) -> dict:
        return {
            "strategy": self.strategy,
            "steps": self.steps,
            "final_loss": round(float(self.losses[-1]), 4) if self.losses else None,
            "final_acc": round(float(self.accuracies[-1][1]), 4)
            if self.accuracies else None,
            "makespan": round(self.virtual_makespan, 3),
            "sync_wait": round(self.sync_wait, 3),
            "comm_MB": round(self.comm_bytes / 2**20, 2),
        }


class BPTTrainer:
    def __init__(self,
                 loss_fn: Callable,                 # (params, batch) -> (loss, aux)
                 init_params,
                 dataset: IDPADataset,
                 train_cfg: TrainConfig,
                 batch_size: int,
                 eval_fn: Optional[Callable] = None,   # (params) -> accuracy
                 speed_factors: Optional[Sequence[float]] = None,
                 accuracy_weighting: str = "normalized"):
        # accuracy_weighting:
        #   "paper"      — Eq. (10) verbatim: scale = gamma * Q.  With small
        #     absolute accuracies early in training this under-applies local
        #     progress (the paper's full-epoch/30-node regime hides it).
        #   "normalized" — beyond-paper fix: Q is divided by its running
        #     mean, so the *relative* contribution weighting the paper wants
        #     is kept while the update magnitude stays ~gamma.
        self.loss_fn = loss_fn
        self.dataset = dataset
        self.tc = train_cfg
        self.batch_size = batch_size
        self.eval_fn = eval_fn
        self.m = train_cfg.outer_nodes
        self.speed = np.asarray(speed_factors if speed_factors is not None
                                else np.ones(self.m), np.float64)
        self.opt = make_optimizer(train_cfg.optimizer)
        self.schedule = warmup_cosine(train_cfg.learning_rate,
                                      train_cfg.warmup_steps,
                                      train_cfg.total_steps)
        self.params0 = init_params
        self.rng = np.random.default_rng(train_cfg.seed)
        self.accuracy_weighting = accuracy_weighting
        self._q_ema = None

        grad_clip = train_cfg.grad_clip

        @jax.jit
        def train_step(params, opt_state, batch, step):
            (loss, aux), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(params, batch)
            if grad_clip:
                grads, _ = clip_by_global_norm(grads, grad_clip)
            lr = self.schedule(step)
            updates, opt_state = self.opt.update(grads, opt_state, params, lr)
            params = apply_updates(params, updates)
            return params, opt_state, loss

        self._train_step = train_step

    def _q_effective(self, q: float) -> float:
        """Relative contribution weight Q (see accuracy_weighting above)."""
        q = max(q, 1e-3)
        if self.accuracy_weighting == "paper":
            return q
        self._q_ema = q if self._q_ema is None else \
            0.9 * self._q_ema + 0.1 * q
        return float(np.clip(q / max(self._q_ema, 1e-3), 0.25, 2.0))

    # ------------------------------------------------------------------
    def _local_round(self, params, opt_state, node: int, step: int):
        """One node's local iteration: ``local_steps`` steps on its stripe."""
        t0 = time.perf_counter()
        loss = None
        for _ in range(self.tc.local_steps):
            batch = self.dataset.node_batch(node, self.batch_size, self.rng)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, loss = self._train_step(
                params, opt_state, batch, jnp.asarray(step, jnp.int32))
        jax.block_until_ready(loss)
        wall = time.perf_counter() - t0
        return params, opt_state, float(loss), wall * self.speed[node]

    def _eval(self, params):
        return float(self.eval_fn(params)) if self.eval_fn else 0.0

    # ------------------------------------------------------------------
    def train(self, rounds: int) -> TrainReport:
        if self.tc.outer_strategy == "sgwu":
            return self._train_sgwu(rounds)
        if self.tc.outer_strategy == "agwu":
            return self._train_agwu(rounds)
        return self._train_sync(rounds)

    # -------------------------- plain sync DP --------------------------
    def _train_sync(self, rounds: int) -> TrainReport:
        """Baseline: synchronous data parallelism (one fused step/round)."""
        params = self.params0
        opt_state = self.opt.init(params)
        losses, accs = [], []
        clock = 0.0
        for r in range(rounds):
            params, opt_state, loss, wall = self._local_round(
                params, opt_state, 0, r)
            clock += wall
            losses.append(loss)
            if self.eval_fn and (r + 1) % 5 == 0:
                accs.append((clock, self._eval(params)))
        return TrainReport("sync", rounds, losses, accs, clock, 0.0, 0,
                           self.dataset.totals, params)

    # ------------------------------ SGWU -------------------------------
    def _train_sgwu(self, rounds: int) -> TrainReport:
        server = ParameterServer(self.params0, self.m)
        opt_states = [self.opt.init(self.params0) for _ in range(self.m)]
        losses, accs = [], []
        clock, sync_wait = 0.0, 0.0
        for r in range(rounds):
            subs, durs = [], np.zeros(self.m)
            for j in range(self.m):
                w, _ = server.pull(j)
                w2, opt_states[j], loss, dur = self._local_round(
                    w, opt_states[j], j, r)
                q = self._eval(w2) if self.eval_fn else 1.0
                subs.append((j, w2, max(q, 1e-3)))  # SGWU normalises in Eq. 7
                durs[j] = dur
            clock += durs.max()
            sync_wait += float((durs.max() - durs).sum())      # Eq. (8)
            server.push_sgwu(subs, virtual_time=clock)
            losses.append(float(np.mean([0.0])) if not subs else loss)
            self.dataset.report_durations(durs)
            if self.eval_fn:
                accs.append((clock, self._eval(server.global_weights)))
        return TrainReport("sgwu", rounds, losses, accs, clock, sync_wait,
                           server.comm_bytes, self.dataset.totals,
                           server.global_weights)

    # ------------------------------ AGWU -------------------------------
    def _train_agwu(self, rounds: int) -> TrainReport:
        server = ParameterServer(self.params0, self.m)
        opt_states = [self.opt.init(self.params0) for _ in range(self.m)]
        losses, accs = [], []
        heap: list[tuple[float, int, int]] = []     # (vtime, node, round)
        local, rounds_done = {}, np.zeros(self.m, np.int64)
        node_durs = np.ones(self.m)

        for j in range(self.m):
            w, _ = server.pull(j)
            local[j] = w
            heapq.heappush(heap, (0.0, j, 0))

        clock = 0.0
        while heap:
            vt, j, r = heapq.heappop(heap)
            w2, opt_states[j], loss, dur = self._local_round(
                local[j], opt_states[j], j, r)
            node_durs[j] = dur
            clock = vt + dur
            q = self._eval(w2) if self.eval_fn else 1.0
            server.push_agwu(j, w2, self._q_effective(q), virtual_time=clock)
            losses.append(loss)
            rounds_done[j] += 1
            if int(rounds_done.min()) >= self.dataset.part.current_batch:
                self.dataset.report_durations(node_durs * self.dataset.totals
                                              / max(self.batch_size, 1))
            if self.eval_fn and len(losses) % self.m == 0:
                accs.append((clock, self._eval(server.global_weights)))
            if rounds_done[j] < rounds:
                w, _ = server.pull(j)
                local[j] = w
                heapq.heappush(heap, (clock, j, int(rounds_done[j])))
        return TrainReport("agwu", int(rounds_done.sum()), losses, accs,
                           clock, 0.0, server.comm_bytes,
                           self.dataset.totals, server.global_weights)
