"""Logical-axis sharding rules (MaxText-style) decoupling models from meshes.

Models annotate activations with *logical* axis names
(``constrain(x, "batch", "seq", "embed")``); the launcher installs a mapping
from logical names to mesh axes (``set_rules``).  With no rules installed
(CPU tests) the calls are no-ops, so the same model code runs everywhere.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["set_rules", "get_rules", "constrain", "constrain_div",
           "rules_scope", "spec_for"]

_RULES: Optional[dict] = None


def set_rules(rules: Optional[dict]) -> None:
    """rules: {logical_name: mesh axis (str | tuple | None)}."""
    global _RULES
    _RULES = rules


def get_rules() -> Optional[dict]:
    return _RULES


@contextlib.contextmanager
def rules_scope(rules: Optional[dict]):
    global _RULES
    prev = _RULES
    _RULES = rules
    try:
        yield
    finally:
        _RULES = prev


def spec_for(*logical_axes: Optional[str]) -> P:
    assert _RULES is not None
    return P(*(_RULES.get(a) if a is not None else None
               for a in logical_axes))


def _axis_size(axis) -> int:
    sizes = (_RULES or {}).get("_axis_sizes", {})
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axis, 1)


def constrain_div(x, *logical_axes: Optional[str]):
    """Like constrain, but silently replicates any dim the mapped mesh
    axis does not divide (needs "_axis_sizes" in the rules)."""
    if _RULES is None:
        return x
    spec = []
    for dim, a in zip(x.shape, logical_axes, strict=True):
        ax = _RULES.get(a) if a is not None else None
        spec.append(ax if ax is not None and dim % _axis_size(ax) == 0
                    else None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain(x, *logical_axes: Optional[str]):
    """Apply with_sharding_constraint if rules are installed, else no-op."""
    if _RULES is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec_for(*logical_axes))
