"""Pluggable outer-layer execution engines for the BPT training loop.

The paper's outer layer is ONE algorithm — pull the global weights, run
``local_steps`` local iterations per node, merge under Eq. 7 (SGWU) or
Eq. 9-10 (AGWU) — with interchangeable execution substrates.  This module
makes each substrate a first-class ``OuterEngine``:

| engine             | backend      | substrate                                  |
|--------------------|--------------|--------------------------------------------|
| ``ScanEngine``     | ``scan``     | sync baseline: one fused scan per round    |
| ``SequentialEngine``| ``sequential``| legacy per-node Python loop (SGWU)        |
| ``VmapEngine``     | ``vmap``     | fused vmap(nodes) x scan(local_steps)      |
| ``ShardMapEngine`` | ``device``   | shard_map on a ``nodes`` or 2-D ``(nodes,  |
|                    |              | model)`` mesh (SGWU; planner inner layer)  |
| ``HeapEngine``     | ``heap``     | AGWU event-ordered heap, host server       |
| ``HeapDeviceEngine``| ``heap-device``| AGWU heap, node-pinned weights + deltas |

``resolve_engine(TrainConfig) -> EnginePlan`` is the SINGLE point that
inspects the ``fused_outer`` / ``device_outer`` / ``mesh_name`` flag
combinations (grep-verifiable: no other module reads them).  It owns every
combination rule, the device-count fallback (recorded in the plan, still
transparent to ``train()``) and every actionable error message.

Engines stream: ``events(rounds)`` yields one ``RoundEvent`` per merge —
per round for SGWU/sync, per push for AGWU — carrying the per-node losses,
the virtual clock, the cumulative Eq. 8 sync-wait and Eq. 11 comm-bytes,
and the pull-able post-merge global weights.  ``BPTTrainer.run`` layers
eval / checkpoint / callback cadences (``TrainHooks``) on top.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Callable, Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_mesh, make_nodes_mesh
from repro.sanitize import sanctioned_sync, sanitized

from .gwu import broadcast_tree, tree_sub
from .param_server import ParameterServer
from .types import TrainConfig

__all__ = [
    "RoundEvent", "TrainHooks", "EnginePlan", "OuterEngine",
    "ScanEngine", "SequentialEngine", "VmapEngine", "ShardMapEngine",
    "HeapEngine", "HeapDeviceEngine", "ENGINES", "engine_config",
    "resolve_engine",
]


# ----------------------------------------------------------------------
# streaming surface
# ----------------------------------------------------------------------
@dataclasses.dataclass
class RoundEvent:
    """One outer-layer merge, as seen by a streaming caller.

    SGWU/sync engines emit one event per round; AGWU engines emit one per
    push (``node`` says which node pushed).  ``params`` is the pull-able
    global weight set AFTER this event's merge — callers may evaluate it,
    checkpoint it via ``repro.checkpointing``, or early-stop on ``loss``.
    """
    round: int                 # event index (SGWU: round; AGWU: push count)
    node_losses: np.ndarray    # losses this event (AGWU: the pushing node's)
    loss: float                # mean of node_losses — the TrainReport entry
    virtual_clock: float       # emulated cluster time (Eq. 8 bookkeeping)
    sync_wait: float           # cumulative synchronization waiting (Eq. 8)
    comm_bytes: int            # cumulative communication volume (Eq. 11)
    params: Any                # global weights after the merge
    node: int = -1             # AGWU: pushing node (-1 for barrier engines)
    accuracy: Optional[float] = None   # filled at the TrainHooks cadence
    # measured per-node durations this event fed to IDPA (the Alg. 3.1
    # feedback signal — hooks observe exactly what the partitioner sees)
    durations: Optional[np.ndarray] = None
    # per-node membership at this event: 0.0 = failed, else the node's
    # current slowdown factor (1.0 = nominal) — FaultSchedule.status_at
    node_status: Optional[np.ndarray] = None


@dataclasses.dataclass
class TrainHooks:
    """Caller-owned cadences for the streaming loop.

    ``eval_every=0`` keeps each engine's historical default: every round
    for SGWU, every 5 rounds for the sync baseline, every m pushes for
    AGWU.  ``checkpoint_every`` saves ``event.params`` through
    ``repro.checkpointing.checkpoint.save`` into ``checkpoint_dir`` and,
    for resumable engines, a ``kind="state"`` train-state checkpoint
    (engine snapshot + parameter-server log + IDPA state + RNG state).
    ``resume=True`` restores the latest train-state checkpoint from
    ``checkpoint_dir`` before the first round — a killed run relaunched
    with the same hooks continues losslessly.
    """
    on_round: Optional[Callable[[RoundEvent], None]] = None
    eval_every: int = 0            # events between accuracy evals (0=default)
    checkpoint_every: int = 0      # events between checkpoints (0=off)
    checkpoint_dir: str = ""
    resume: bool = False           # restore latest state ckpt before round 1


# ----------------------------------------------------------------------
# the single config-resolution point
# ----------------------------------------------------------------------
@dataclasses.dataclass
class EnginePlan:
    """Resolved execution plan: which engine runs, and why.

    ``backend`` is the substrate that will actually execute; ``requested``
    is what the flags asked for.  When they differ, ``fallback`` carries
    the human-readable reason (e.g. too few devices) — the fallback stays
    transparent to ``train()`` but is recorded here and surfaced on
    ``TrainReport.fallback``.
    """
    engine_cls: type
    backend: str               # scan|sequential|vmap|device|heap|heap-device
    strategy: str              # sync|sgwu|agwu
    requested: str             # backend the config asked for
    mesh: Any = None           # the `nodes` mesh (ShardMapEngine only)
    fallback: str = ""         # "" unless backend != requested
    devices: Any = None        # the device pool the plan was resolved
                               # against (HeapDeviceEngine pins node j to
                               # devices[j]; ShardMapEngine via ``mesh``)


def _nodes_mesh(cfg: TrainConfig, m: int, devices):
    """The `nodes` mesh for the device-sharded outer layer, or None when
    the backend has too few devices (the transparent fallback).  A
    ``mesh_name`` whose `nodes` axis mismatches ``outer_nodes`` is a
    config bug, not a capacity problem, and raises.  2-D hybrid meshes
    (``nodesNxmodelK``) pass: only the ``nodes`` axis is validated here;
    the ``model`` axis is the planner's."""
    try:
        mesh = make_mesh(cfg.mesh_name, devices=devices) if cfg.mesh_name \
            else make_nodes_mesh(m, devices=devices)
    except RuntimeError:
        return None
    if "nodes" not in mesh.axis_names or mesh.shape["nodes"] != m:
        raise ValueError(
            f"mesh {cfg.mesh_name!r} needs a `nodes` axis of size "
            f"{m}, has axes {dict(mesh.shape)}")
    return mesh


def resolve_engine(cfg: TrainConfig, devices: Optional[Sequence] = None
                   ) -> EnginePlan:
    """Map a TrainConfig (+ available devices) to an execution plan.

    The ONLY place in the codebase that inspects the ``fused_outer`` /
    ``device_outer`` / ``mesh_name`` combinations.  Every rule:

    - ``sync``: always ``ScanEngine``; rejects ``uneven_batches``.
    - ``sgwu`` + ``device_outer``: ``ShardMapEngine`` on the ``mesh_name``
      mesh (or an auto 1-D `nodes` mesh); a 2-D ``nodesNxmodelK`` mesh
      turns on the per-layer inner planner (``core.planner``); mesh
      without a matching `nodes` axis raises; too few devices falls back
      to ``VmapEngine`` with the reason recorded in
      ``EnginePlan.fallback``.
    - ``sgwu`` + ``fused_outer``: ``VmapEngine``.
    - ``sgwu`` sequential: ``SequentialEngine``; rejects
      ``uneven_batches`` (only stacked rounds realize masked stripes).
    - ``agwu``: ``HeapDeviceEngine`` when ``device_outer`` and >= m
      devices exist (node-pinned weights, Eq. 10 delta pushes), else
      ``HeapEngine`` (fallback recorded); rejects ``uneven_batches``.
    """
    if devices is None:
        devices = jax.devices()
    m = cfg.outer_nodes
    if cfg.outer_strategy == "sgwu":
        if cfg.device_outer:
            mesh = _nodes_mesh(cfg, m, devices)
            if mesh is not None:
                return EnginePlan(ShardMapEngine, "device", "sgwu",
                                  "device", mesh=mesh)
            return EnginePlan(
                VmapEngine, "vmap", "sgwu", "device",
                fallback=f"device_outer needs {m} devices, have "
                f"{len(devices)}: running the fused vmap emulation")
        if cfg.fused_outer:
            return EnginePlan(VmapEngine, "vmap", "sgwu", "vmap")
        if cfg.uneven_batches:
            raise ValueError(
                "uneven_batches needs the fused or device outer path")
        return EnginePlan(SequentialEngine, "sequential", "sgwu",
                          "sequential")
    if cfg.uneven_batches:
        # only the stacked-round SGWU paths realize the padded+masked
        # stripes; silently training with uniform batches would fake
        # the heterogeneity the flag promises
        raise ValueError(
            "uneven_batches needs outer_strategy='sgwu' (the fused or "
            f"device outer path), not {cfg.outer_strategy!r}")
    if cfg.outer_strategy == "agwu":
        if cfg.device_outer:
            if len(devices) >= m:
                return EnginePlan(HeapDeviceEngine, "heap-device", "agwu",
                                  "heap-device", devices=list(devices))
            return EnginePlan(
                HeapEngine, "heap", "agwu", "heap-device",
                fallback=f"device_outer needs {m} devices, have "
                f"{len(devices)}: running the host-heap AGWU path")
        return EnginePlan(HeapEngine, "heap", "agwu", "heap")
    return EnginePlan(ScanEngine, "scan", "sync", "scan")


# ----------------------------------------------------------------------
# engines
# ----------------------------------------------------------------------
class OuterEngine:
    """One execution substrate for the outer layer.

    Protocol: ``setup(rounds) -> state`` builds the parameter server /
    optimizer state / jitted round callable; ``run_round(state, r) ->
    RoundEvent`` executes one merge event; ``events(rounds)`` drives the
    two as a generator.  Engines never read TrainConfig substrate flags —
    ``resolve_engine`` already decided everything and recorded it in the
    ``EnginePlan`` they are constructed with.

    Crash-safe resumption: ``snapshot(state) -> (arrays, scalars)``
    captures everything ``setup`` + the rounds so far produced — a pytree
    of weight/optimizer arrays plus a JSON-able scalar dict (server
    version log, clocks, heap entries).  ``restore_snapshot(state,
    arrays, scalars)`` rebuilds a fresh ``setup`` state in place, after
    which ``events(rounds, start=n, state=state)`` continues from event
    ``n`` exactly where the killed run stopped.  Engines that return
    ``None`` from ``snapshot`` are not resumable (no state checkpoint is
    written for them).
    """
    backend = ""
    strategy = ""

    def __init__(self, trainer, plan: EnginePlan):
        self.t = trainer
        self.plan = plan
        # historical eval cadence (events between accuracy measurements);
        # TrainHooks.eval_every overrides
        self.default_eval_every = 1

    def total_events(self, rounds: int) -> int:
        return rounds

    def setup(self, rounds: int):
        raise NotImplementedError

    def run_round(self, state, r: int) -> RoundEvent:
        raise NotImplementedError

    def events(self, rounds: int, start: int = 0,
               state: Any = None) -> Iterator[RoundEvent]:
        state = self.setup(rounds) if state is None else state
        for r in range(start, self.total_events(rounds)):
            # the round body runs under the transfer-guard sanitizer
            # (REPRO_SANITIZE=1): implicit host<->device transfers raise;
            # the event is yielded OUTSIDE the scope so consumers
            # (eval / checkpoint hooks) may pull freely
            with sanitized(f"{self.backend}.run_round"):
                ev = self.run_round(state, r)
            yield ev

    def snapshot(self, state):
        """``(arrays, scalars)`` capturing the resumable train state, or
        ``None`` for engines that do not support resumption."""
        return None

    def restore_snapshot(self, state, arrays, scalars) -> None:
        raise NotImplementedError(
            f"{type(self).__name__} does not support resumption")

    # -- fault-schedule access ------------------------------------------
    @property
    def faults(self):
        """The trainer's FaultSchedule, or None when churn-free."""
        f = self.t.faults
        return None if (f is None or f.empty) else f


# -------------------------- sync baseline ---------------------------
@dataclasses.dataclass
class _ScanState:
    params: Any
    opt_state: Any
    clock: float = 0.0


class ScanEngine(OuterEngine):
    """Synchronous single-node data parallelism (one fused scan/round)."""
    backend = "scan"
    strategy = "sync"

    def __init__(self, trainer, plan):
        super().__init__(trainer, plan)
        self.default_eval_every = 5

    def setup(self, rounds):
        t = self.t
        if self.faults is not None:
            raise ValueError(
                "the sync baseline has no outer-layer membership to churn; "
                "fault schedules need outer_strategy='sgwu' or 'agwu'")
        return _ScanState(t.params0, t.opt.init(t.params0))

    def snapshot(self, st):
        arrays = {"params": st.params, "opt": st.opt_state}
        return arrays, {"clock": st.clock}

    def restore_snapshot(self, st, arrays, scalars):
        # checkpoints restore as numpy trees: commit them explicitly so
        # the next dispatch is transfer-free under the sanitizer
        st.params, st.opt_state = jax.device_put(
            (arrays["params"], arrays["opt"]))
        st.clock = float(scalars["clock"])

    def run_round(self, st, r):
        t = self.t
        batches = [t.dataset.node_batch(0, t.batch_size, t.rng)
                   for _ in range(t.tc.local_steps)]
        # stack on host, then ONE explicit placement — the jit dispatch
        # below never uploads implicitly (transfer-guard clean)
        stacked = jax.device_put({k: np.stack([b[k] for b in batches])
                                  for k in batches[0]})
        # same contract as the stacked engines: the clock starts after the
        # host batch draw, so the virtual time is compute-only
        t0 = time.perf_counter()
        st.params, st.opt_state, loss = t._scan_round(
            st.params, st.opt_state, stacked, jax.device_put(np.int32(r)))
        loss = float(sanctioned_sync(loss, "scan.loss"))
        st.clock += (time.perf_counter() - t0) * t.speed[0]
        return RoundEvent(round=r, node_losses=np.asarray([loss]),
                          loss=loss, virtual_clock=st.clock,
                          sync_wait=0.0, comm_bytes=0, params=st.params)


# ------------------------- stacked SGWU -----------------------------
@dataclasses.dataclass
class _StackedState:
    server: ParameterServer
    stacked_opt: Any
    round_fn: Callable
    batch_sharding: Any
    clock: float = 0.0
    sync_wait: float = 0.0


class _StackedSGWUEngine(OuterEngine):
    """The stacked SGWU round loop shared by the fused-vmap and
    device-sharded engines — they differ only in the server mode, the
    round callable and the batch placement, so the Eq. 7/8 bookkeeping
    lives exactly once.

    Per-node virtual durations are an equal share of the measured round
    wall scaled by the node speed factors — the heterogeneity emulation
    the sequential loop derived from per-node measurement.
    """
    strategy = "sgwu"

    def _build(self):
        """-> (server, stacked_opt, round_fn, batch_sharding)"""
        raise NotImplementedError

    def setup(self, rounds):
        return _StackedState(*self._build())

    def snapshot(self, st):
        arrays = {"global": st.server.global_weights, "opt": st.stacked_opt}
        scalars = {"clock": st.clock, "sync_wait": st.sync_wait,
                   "server": st.server.state_dict()}
        return arrays, scalars

    def restore_snapshot(self, st, arrays, scalars):
        g, opt = arrays["global"], arrays["opt"]
        mesh = self.plan.mesh
        if mesh is not None:       # re-establish the device-resident layout
            P = jax.sharding.PartitionSpec
            g = jax.device_put(g, jax.sharding.NamedSharding(mesh, P()))
            opt = jax.device_put(
                opt, jax.sharding.NamedSharding(mesh, P("nodes")))
        else:                      # commit the numpy checkpoint trees so
            g, opt = jax.device_put((g, opt))   # dispatches stay implicit-
        st.server.global_weights = g            # transfer-free (sanitizer)
        st.server.load_state_dict(scalars["server"])
        st.stacked_opt = opt
        st.clock = float(scalars["clock"])
        st.sync_wait = float(scalars["sync_wait"])

    def run_round(self, st, r):
        t = self.t
        faults = self.faults
        status = faults.status_at(r, t.m) if faults else None
        alive = status > 0.0 if status is not None \
            else np.ones(t.m, dtype=bool)
        if not alive.any():
            raise RuntimeError(
                f"fault schedule leaves no node alive at round {r}")
        stacked_w, _ = st.server.pull_all_stacked(
            active=alive if faults else None)
        batches = t.dataset.stacked_round_batches(
            t.batch_size, t.tc.local_steps, t.rng,
            uneven=t.tc.uneven_batches)
        # explicit placement even on the fused single-device path
        # (batch_sharding None -> default device): the round dispatch
        # below must never upload the host batches implicitly
        batches = jax.device_put(batches, st.batch_sharding)
        # the Eq. 8 wall starts AFTER the host batch draw + device
        # placement: data prep is the main server's work, not node compute,
        # and must not pollute the sync-wait or the IDPA duration feedback
        t0 = time.perf_counter()
        stacked_w, st.stacked_opt, node_losses = st.round_fn(
            stacked_w, st.stacked_opt, batches, jax.device_put(np.int32(r)))
        # the Eq. 8 measurement boundary: blocking here IS the wall
        # semantics, so the pull is a sanctioned sync, not a hidden one
        node_losses = sanctioned_sync(node_losses, "round.losses")
        wall = time.perf_counter() - t0
        # a dead node's lane still computes (the fused dispatch is
        # all-or-nothing) but its result never reaches the barrier: its
        # duration is 0 (no push to wait for), its merge weight is 0, and
        # it re-enters automatically at the next round's rebroadcast pull
        durs = (wall / t.m) * t.speed
        if status is not None:
            durs = durs * status             # slow factors; dead lanes -> 0
        st.clock += float(durs[alive].max())
        st.sync_wait += float((durs[alive].max() - durs[alive]).sum())
        if t.eval_fn:
            qs = np.asarray(t._eval_nodes(stacked_w), dtype=np.float64)
        else:
            qs = np.ones(t.m)                # SGWU normalises in Eq. 7
        qs = np.where(alive, qs, 0.0)        # Eq. 7 excludes the dead
        st.server.push_sgwu_stacked(stacked_w, qs, virtual_time=st.clock,
                                    active=alive if faults else None)
        t.dataset.report_durations(durs,
                                   active=alive if faults else None)
        loss = float(node_losses[alive].mean())
        return RoundEvent(round=r, node_losses=node_losses, loss=loss,
                          virtual_clock=st.clock, sync_wait=st.sync_wait,
                          comm_bytes=st.server.comm_bytes,
                          params=st.server.global_weights,
                          durations=durs.copy(), node_status=status)


class VmapEngine(_StackedSGWUEngine):
    """Fused outer layer: the m nodes' round is ONE jitted dispatch.

    Node-stacked params/opt-states flow ``pull_all_stacked`` ->
    ``_fused_round`` (vmap over nodes, scan over local steps, stacked
    buffers donated) -> ``push_sgwu_stacked`` (jitted Eq. 7 merge on the
    stack, donated).
    """
    backend = "vmap"

    def _build(self):
        t = self.t
        server = ParameterServer(t.params0, t.m)
        stacked_opt = broadcast_tree(t.opt.init(t.params0), t.m)
        return server, stacked_opt, t._fused_round, None


class ShardMapEngine(_StackedSGWUEngine):
    """Device-sharded outer layer: the paper's m physical nodes.

    Identical round structure to ``VmapEngine``, but the node-stacked
    pytrees are placed with ``NamedSharding`` over the plan mesh's
    `nodes` axis (node j resident on device j), the round runs under
    ``shard_map``, and the Eq. 7 merge is an on-device weighted
    all-reduce inside the device-resident ParameterServer — the global
    weights never funnel through host or a single device.

    On a 2-D ``(nodes, model)`` mesh (the ``nodesNxmodelK`` family) the
    engine additionally plans per-layer inner parallelism:
    ``core.planner.plan_network`` emits a ``NetworkPlan`` whose per-layer
    PartitionSpecs / kernel tiles the round executes under a
    ``plan_scope`` — ``self.netplan`` holds the plan and
    ``self.executed`` accumulates the LayerPlans the kernels actually
    consumed, so tests can assert scheduled == executed.  Params and
    opt state stay replicated over ``model`` (each node's K devices
    cooperate on ITS subnetwork); the Eq. 7 merge psum remains a pure
    ``nodes`` collective.
    """
    backend = "device"
    netplan = None      # NetworkPlan (2-D meshes only)

    def __init__(self, trainer, plan):
        super().__init__(trainer, plan)
        self.executed = []   # LayerPlans consumed by kernel dispatches

    def _build(self):
        t, mesh = self.t, self.plan.mesh
        server = ParameterServer(t.params0, t.m, mesh=mesh)
        node_sharding = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("nodes"))
        stacked_opt = jax.device_put(
            broadcast_tree(t.opt.init(t.params0), t.m), node_sharding)
        if dict(mesh.shape).get("model", 1) > 1:
            from repro.core import planner as planner_mod
            netplan = planner_mod.plan_network(
                t.model_cfg, mesh, batch_size=t.batch_size,
                family=t.plan_family)
            self.netplan = netplan
            base = t._get_device_round(mesh, netplan)
            engine = self

            def round_fn(stacked_w, stacked_opt, batches, step):
                # the scope is consumed at TRACE time: the first call per
                # (mesh, plan) records the executed LayerPlans; cached
                # re-dispatches trace nothing new (like fallback_events)
                with planner_mod.plan_scope(netplan) as sc:
                    out = base(stacked_w, stacked_opt, batches, step)
                engine.executed.extend(sc.executed)
                return out

            batch_sharding = jax.sharding.NamedSharding(
                mesh, netplan.batch_spec)
            return server, stacked_opt, round_fn, batch_sharding
        return server, stacked_opt, t._get_device_round(mesh), node_sharding


# ------------------------ sequential SGWU ---------------------------
@dataclasses.dataclass
class _SequentialState:
    server: ParameterServer
    opt_states: list
    clock: float = 0.0
    sync_wait: float = 0.0


class SequentialEngine(OuterEngine):
    """Legacy emulation: one jitted step per node per local step.

    Kept as the reference the fused path is regression-tested against
    (and the baseline ``benchmarks/outer_loop.py`` measures)."""
    backend = "sequential"
    strategy = "sgwu"

    def setup(self, rounds):
        t = self.t
        return _SequentialState(ParameterServer(t.params0, t.m),
                                [t.opt.init(t.params0) for _ in range(t.m)])

    def snapshot(self, st):
        arrays = {"global": st.server.global_weights,
                  "opt": {str(j): s for j, s in enumerate(st.opt_states)}}
        scalars = {"clock": st.clock, "sync_wait": st.sync_wait,
                   "server": st.server.state_dict()}
        return arrays, scalars

    def restore_snapshot(self, st, arrays, scalars):
        # commit the numpy checkpoint trees (sanitizer: no implicit h2d)
        st.server.global_weights = jax.device_put(arrays["global"])
        st.server.load_state_dict(scalars["server"])
        st.opt_states = [jax.device_put(arrays["opt"][str(j)])
                         for j in range(len(st.opt_states))]
        st.clock = float(scalars["clock"])
        st.sync_wait = float(scalars["sync_wait"])

    def run_round(self, st, r):
        t = self.t
        faults = self.faults
        status = faults.status_at(r, t.m) if faults else None
        alive = status > 0.0 if status is not None \
            else np.ones(t.m, dtype=bool)
        if not alive.any():
            raise RuntimeError(
                f"fault schedule leaves no node alive at round {r}")
        subs, durs = [], np.zeros(t.m)
        node_losses = np.zeros(t.m)
        for j in range(t.m):
            if not alive[j]:
                # a failed node never pulls, computes, or pushes: it
                # misses the barrier and Eq. 7 excludes it (weight 0)
                subs.append((j, None, 0.0))
                continue
            w, _ = st.server.pull(j)
            w2, st.opt_states[j], loss, dur = t._local_round(
                w, st.opt_states[j], j, r)
            q = t._eval(w2) if t.eval_fn else 1.0
            subs.append((j, w2, max(q, 1e-3)))  # SGWU normalises in Eq. 7
            durs[j] = dur * (status[j] if status is not None else 1.0)
            node_losses[j] = loss
        st.clock += float(durs[alive].max())
        st.sync_wait += float((durs[alive].max() - durs[alive]).sum())
        st.server.push_sgwu(subs, virtual_time=st.clock)
        t.dataset.report_durations(durs,
                                   active=alive if faults else None)
        return RoundEvent(round=r, node_losses=node_losses,
                          loss=float(node_losses[alive].mean()),
                          virtual_clock=st.clock, sync_wait=st.sync_wait,
                          comm_bytes=st.server.comm_bytes,
                          params=st.server.global_weights,
                          durations=durs.copy(), node_status=status)


# ----------------------------- AGWU ---------------------------------
@dataclasses.dataclass
class _HeapState:
    server: ParameterServer
    opt_states: list
    heap: list                     # (virtual_time, node, round, epoch)
    local: dict
    base_local: dict
    rounds_done: np.ndarray
    node_durs: np.ndarray
    rounds: int
    clock: float = 0.0
    # --- node churn ---
    down: set = dataclasses.field(default_factory=set)
    slow: np.ndarray = None        # per-node duration multipliers
    epoch: np.ndarray = None       # bumped on fail: stales in-flight work
    fault_cursor: int = 0          # next unapplied FaultSchedule event


class HeapEngine(OuterEngine):
    """AGWU keeps its event-ordered heap (the ordering IS the algorithm).

    One ``RoundEvent`` per push: ``total_events`` is m x rounds.  The
    host-server variant ships full local weights through a pre-jitted,
    buffer-donating Eq. 10 push.

    Node churn: fault-schedule transitions are keyed on the EVENT index
    (the i-th successful push) and applied before each heap pop.  A
    ``fail`` bumps the node's epoch — its in-flight heap entry becomes
    stale and is dropped at pop time (the push never arrives at the
    server, Eq. 10 never sees the lost work).  A ``rejoin`` re-pulls the
    current global weights and re-enters the heap at the current virtual
    clock with a FRESH base version, so its next gamma (Eq. 10) reflects
    the staleness it actually has.  A ``slow`` multiplies the node's
    measured durations, which flows straight into the IDPA feedback.
    """
    backend = "heap"
    strategy = "agwu"
    device_nodes = False

    def __init__(self, trainer, plan):
        super().__init__(trainer, plan)
        self.default_eval_every = trainer.m     # one eval per virtual round

    def total_events(self, rounds):
        return rounds * self.t.m

    def _pull(self, st, j):
        w, _ = st.server.pull(j)
        if self.device_nodes:
            w = jax.device_put(w, self.plan.devices[j])
            st.base_local[j] = w       # W(k) snapshot, node-resident
        return w

    def setup(self, rounds):
        t = self.t
        server = ParameterServer(t.params0, t.m)
        if not self.device_nodes:
            server.warmup_agwu()   # compile the donated Eq. 10 push up front
        st = _HeapState(server, [t.opt.init(t.params0) for _ in range(t.m)],
                        [], {}, {}, np.zeros(t.m, np.int64), np.ones(t.m),
                        rounds, slow=np.ones(t.m),
                        epoch=np.zeros(t.m, np.int64))
        for j in range(t.m):
            if self.device_nodes:
                st.opt_states[j] = jax.device_put(st.opt_states[j],
                                                  self.plan.devices[j])
            st.local[j] = self._pull(st, j)
            heapq.heappush(st.heap, (0.0, j, 0, 0))
        return st

    # ---------------- churn transitions ------------------------------
    def _apply_faults(self, st, i):
        faults = self.faults
        if faults is None:
            return
        evs = faults.events
        while st.fault_cursor < len(evs) and evs[st.fault_cursor].round <= i:
            e = evs[st.fault_cursor]
            st.fault_cursor += 1
            if e.kind == "fail":
                st.down.add(e.node)
                st.epoch[e.node] += 1       # in-flight work is lost
            elif e.kind == "rejoin":
                st.down.discard(e.node)
                if st.rounds_done[e.node] < st.rounds:
                    st.local[e.node] = self._pull(st, e.node)
                    heapq.heappush(
                        st.heap, (st.clock, e.node,
                                  int(st.rounds_done[e.node]),
                                  int(st.epoch[e.node])))
            else:                           # "slow"
                st.slow[e.node] = e.factor

    def _status(self, st):
        status = st.slow.copy()
        for j in st.down:
            status[j] = 0.0
        return status

    def _process(self, st, i) -> Optional[RoundEvent]:
        """Pop one heap entry; None = the push was lost to a failure."""
        t = self.t
        vt, j, r, epoch = heapq.heappop(st.heap)
        if j in st.down or epoch != int(st.epoch[j]):
            return None                     # stale push: node died mid-round
        w2, st.opt_states[j], loss, dur = t._local_round(
            st.local[j], st.opt_states[j], j, r)
        dur *= float(st.slow[j])
        st.node_durs[j] = dur
        st.clock = vt + dur
        q = t._eval(w2) if t.eval_fn else 1.0
        if self.device_nodes:
            delta = tree_sub(w2, st.base_local[j])   # on node j's device
            st.server.push_agwu_delta(j, delta, t._q_effective(q),
                                      virtual_time=st.clock)
        else:
            st.server.push_agwu(j, w2, t._q_effective(q),
                                virtual_time=st.clock,
                                donate=True)  # w2 is dead after the push
        st.rounds_done[j] += 1
        alive = np.array([jj not in st.down for jj in range(t.m)])
        if alive.any() and \
                int(st.rounds_done[alive].min()) >= \
                t.dataset.part.current_batch:
            t.dataset.report_durations(
                st.node_durs * t.dataset.totals / max(t.batch_size, 1),
                active=alive if st.down else None)
        if st.rounds_done[j] < st.rounds:
            st.local[j] = self._pull(st, j)
            heapq.heappush(st.heap, (st.clock, j, int(st.rounds_done[j]),
                                     int(st.epoch[j])))
        return RoundEvent(round=i, node=j,
                          node_losses=np.asarray([loss]), loss=loss,
                          virtual_clock=st.clock, sync_wait=0.0,
                          comm_bytes=st.server.comm_bytes,
                          params=st.server.global_weights,
                          durations=st.node_durs.copy(),
                          node_status=self._status(st)
                          if self.faults else None)

    def run_round(self, st, i):
        ev = None
        while ev is None:
            ev = self._process(st, i)
        return ev

    def events(self, rounds, start=0, state=None):
        st = self.setup(rounds) if state is None else state
        # a restored snapshot of a COMPLETED shorter run holds an empty
        # heap (each node finished its configured rounds, so nothing was
        # re-pulled); extending ``rounds`` on resume re-seeds those nodes
        # at the current clock — the same transition as a rejoin.  Fresh
        # and mid-run states already carry current-epoch entries, so
        # this is a no-op for them.
        live = {(j, e) for _, j, _, e in st.heap}
        for j in range(self.t.m):
            if j in st.down or st.rounds_done[j] >= st.rounds:
                continue
            if (j, int(st.epoch[j])) not in live:
                st.local[j] = self._pull(st, j)
                heapq.heappush(st.heap, (st.clock, j,
                                         int(st.rounds_done[j]),
                                         int(st.epoch[j])))
        i = start
        budget = self.total_events(rounds)
        while i < budget:
            self._apply_faults(st, i)
            if not st.heap:
                # permanent failures: the dead nodes' rounds never run;
                # the surviving nodes have completed all of theirs
                return
            with sanitized(f"{self.backend}.push"):
                ev = self._process(st, i)
            if ev is None:
                continue                    # dropped (lost) push
            yield ev
            i += 1

    # ---------------- crash-safe snapshot ----------------------------
    def snapshot(self, st):
        t = self.t
        arrays = {
            "global": st.server.global_weights,
            "local": {str(j): st.local[j] for j in range(t.m)},
            "opt": {str(j): s for j, s in enumerate(st.opt_states)},
            "base": {str(j): (st.base_local[j] if self.device_nodes
                              else st.server._base[j])
                     for j in range(t.m)},
        }
        scalars = {
            "clock": st.clock,
            "heap": [[vt, j, r, e] for vt, j, r, e in st.heap],
            "rounds_done": st.rounds_done.tolist(),
            "node_durs": st.node_durs.tolist(),
            "down": sorted(st.down),
            "slow": st.slow.tolist(),
            "epoch": st.epoch.tolist(),
            "fault_cursor": st.fault_cursor,
            "server": st.server.state_dict(),
        }
        return arrays, scalars

    def restore_snapshot(self, st, arrays, scalars):
        t = self.t
        # commit the numpy checkpoint trees (sanitizer: no implicit h2d)
        st.server.global_weights = jax.device_put(arrays["global"])
        st.server.load_state_dict(scalars["server"])
        for j in range(t.m):
            local, opt = arrays["local"][str(j)], arrays["opt"][str(j)]
            base = arrays["base"][str(j)]
            if self.device_nodes:
                local = jax.device_put(local, self.plan.devices[j])
                opt = jax.device_put(opt, self.plan.devices[j])
                base = jax.device_put(base, self.plan.devices[j])
                st.base_local[j] = base
            else:
                local, opt, base = jax.device_put((local, opt, base))
                st.server._base[j] = base
            st.local[j] = local
            st.opt_states[j] = opt
        st.heap = [(float(vt), int(j), int(r), int(e))
                   for vt, j, r, e in scalars["heap"]]
        heapq.heapify(st.heap)
        st.rounds_done = np.asarray(scalars["rounds_done"], np.int64)
        st.node_durs = np.asarray(scalars["node_durs"], np.float64)
        st.down = set(scalars["down"])
        st.slow = np.asarray(scalars["slow"], np.float64)
        st.epoch = np.asarray(scalars["epoch"], np.int64)
        st.fault_cursor = int(scalars["fault_cursor"])
        st.clock = float(scalars["clock"])


class HeapDeviceEngine(HeapEngine):
    """AGWU with each node's weights/opt-state pinned to its own device;
    a push computes the Eq. 10 delta W_j(k) - W(k) on the node's device
    and ships ONLY the delta to the server (``push_agwu_delta``)."""
    backend = "heap-device"
    device_nodes = True


# ----------------------------------------------------------------------
# engine selection by name (drivers / benchmarks)
# ----------------------------------------------------------------------
ENGINES = {
    "scan": ScanEngine,
    "sequential": SequentialEngine,
    "vmap": VmapEngine,
    "device": ShardMapEngine,
    "heap": HeapEngine,
    "heap-device": HeapDeviceEngine,
}

_ENGINE_CONFIGS = {
    "scan": dict(outer_strategy="sync"),
    "sequential": dict(outer_strategy="sgwu", fused_outer=False,
                       device_outer=False),
    "vmap": dict(outer_strategy="sgwu", fused_outer=True,
                 device_outer=False),
    "device": dict(outer_strategy="sgwu", device_outer=True),
    "heap": dict(outer_strategy="agwu", device_outer=False),
    "heap-device": dict(outer_strategy="agwu", device_outer=True),
}


def engine_config(name: str, **overrides) -> dict:
    """TrainConfig kwargs that ``resolve_engine`` maps to the named engine.

    Drivers select substrates by name (``--engine vmap``) instead of
    setting flag combinations by hand; device-count fallbacks still apply
    (a ``device`` request on a small host runs — and records — ``vmap``).
    """
    if name not in _ENGINE_CONFIGS:
        raise ValueError(
            f"unknown engine {name!r}: choose one of {sorted(_ENGINE_CONFIGS)}")
    return {**_ENGINE_CONFIGS[name], **overrides}
