"""Fault schedules for the elastic outer layer: node churn and slowdowns.

The paper's AGWU/IDPA strategies exist to absorb heterogeneity and
stragglers (§3); a ``FaultSchedule`` makes that claim testable by injecting
membership changes mid-run.  A schedule is a sorted list of ``FaultEvent``s
keyed on an integer *event index* whose meaning depends on the consumer:

* barrier engines (sync / SGWU) and ``ClusterSim._run_sgwu`` apply events
  at the START of the named round,
* the AGWU heap engines and ``ClusterSim._run_agwu`` apply events before
  processing the named *push* (the same index ``RoundEvent.round`` carries
  for AGWU streams), so "fail at 5" means the node is dead from the 5th
  merge event onward.

Semantics per kind:

* ``fail``   — the node's in-flight work is LOST (its AGWU push simply
  never arrives on the event heap; its SGWU submission is excluded from
  the Eq. 7 merge with weight 0) and it stops computing.
* ``rejoin`` — the node re-pulls the current global weights and resumes.
  Because every SGWU pull rebroadcasts the merged weights, and an AGWU
  rejoin is an ordinary fresh pull, a rejoined node is in sync by
  construction — no special recovery path exists to get wrong.
* ``slow``   — the node's virtual durations are multiplied by ``factor``
  from that point on (1.0 restores nominal speed).  IDPA sees the slowdown
  through the measured-duration feedback and re-allocates.

Dead nodes keep the samples IDPA already allocated to them (§3.3.1: no
migration) but receive nothing from later allocation batches — the
partitioner is fed an ``active`` mask alongside the measured durations.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable, Sequence

import numpy as np

__all__ = ["FaultEvent", "FaultSchedule"]

_KINDS = ("fail", "rejoin", "slow")


@dataclasses.dataclass(frozen=True, order=True)
class FaultEvent:
    """One membership/speed transition: ``kind`` applied to ``node`` at
    event index ``round`` (see module docstring for the per-engine index
    semantics).  ``factor`` is the slowdown multiplier for ``slow``."""
    round: int
    node: int
    kind: str
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"FaultEvent.kind={self.kind!r}: choose one of {_KINDS}")
        if self.round < 0 or self.node < 0:
            raise ValueError(
                f"FaultEvent round/node must be >= 0, got "
                f"({self.round}, {self.node})")
        if self.kind == "slow" and not self.factor > 0:
            raise ValueError(
                f"FaultEvent.factor={self.factor}: slowdown must be > 0")


# one CLI/spec atom: kind:node@round[xfactor]
_SPEC = re.compile(
    r"^(?P<kind>fail|rejoin|slow):(?P<node>\d+)@(?P<round>\d+)"
    r"(?:x(?P<factor>[0-9.]+))?$")


class FaultSchedule:
    """An ordered set of fault events plus status-replay queries.

    ``status_at(r, m)`` replays every event with index <= ``r`` and returns
    the per-node status vector: ``0.0`` for a failed node, otherwise the
    current slowdown factor (``1.0`` = nominal).  Engines stamp this vector
    onto ``RoundEvent.node_status`` so hooks observe membership.
    """

    def __init__(self, events: Iterable[FaultEvent],
                 num_nodes: int | None = None):
        self.events: tuple[FaultEvent, ...] = tuple(sorted(events))
        if num_nodes is not None:
            bad = [e for e in self.events if e.node >= num_nodes]
            if bad:
                raise ValueError(
                    f"fault schedule names node {bad[0].node} but the run "
                    f"has only {num_nodes} nodes")
        # a rejoin must follow a fail of the same node
        down: set[int] = set()
        for e in self.events:
            if e.kind == "fail":
                if e.node in down:
                    raise ValueError(
                        f"node {e.node} fails twice without a rejoin "
                        f"(second fail at {e.round})")
                down.add(e.node)
            elif e.kind == "rejoin":
                if e.node not in down:
                    raise ValueError(
                        f"node {e.node} rejoins at {e.round} without a "
                        "preceding fail")
                down.discard(e.node)

    # ------------------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: str,
                  num_nodes: int | None = None) -> "FaultSchedule":
        """Parse ``"fail:1@3,rejoin:1@6,slow:2@4x2.5"`` (CLI surface)."""
        events = []
        for atom in filter(None, (s.strip() for s in spec.split(","))):
            m = _SPEC.match(atom)
            if not m:
                raise ValueError(
                    f"bad fault spec {atom!r}: expected "
                    "kind:node@round[xfactor] with kind in "
                    f"{_KINDS}, e.g. fail:1@3 or slow:2@4x2.5")
            events.append(FaultEvent(
                round=int(m["round"]), node=int(m["node"]), kind=m["kind"],
                factor=float(m["factor"]) if m["factor"] else 1.0))
        return cls(events, num_nodes=num_nodes)

    def validate_nodes(self, num_nodes: int) -> None:
        """Raise if any event names a node outside ``range(num_nodes)``."""
        bad = [e for e in self.events if e.node >= num_nodes]
        if bad:
            raise ValueError(
                f"fault schedule names node {bad[0].node} but the run "
                f"has only {num_nodes} nodes")

    @property
    def empty(self) -> bool:
        return not self.events

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)

    # ------------------------------------------------------------------
    def status_at(self, r: int, m: int) -> np.ndarray:
        """Per-node status after every event with index <= ``r``:
        0.0 = failed, else the node's current slowdown factor."""
        slow = np.ones(m, dtype=np.float64)
        alive = np.ones(m, dtype=bool)
        for e in self.events:
            if e.round > r:
                break
            if e.kind == "fail":
                alive[e.node] = False
            elif e.kind == "rejoin":
                alive[e.node] = True
            else:
                slow[e.node] = e.factor
        return np.where(alive, slow, 0.0)

    def alive_at(self, r: int, m: int) -> np.ndarray:
        return self.status_at(r, m) > 0.0

    def between(self, lo: int, hi: int) -> Sequence[FaultEvent]:
        """Events with index in ``(lo, hi]`` — the incremental-replay slice
        event-driven consumers apply between two processed indices."""
        return [e for e in self.events if lo < e.round <= hi]
