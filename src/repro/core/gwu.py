"""Global Weight Updating strategies — SGWU (Eq. 7) and AGWU (Eq. 9-10).

Both operate on arbitrary JAX pytrees so the same code path serves the
paper's CNN and every assigned LLM architecture.  The update math is jitted;
the versioning/bookkeeping lives in ``param_server.ParameterServer``.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["sgwu_merge", "sgwu_merge_stacked", "sgwu_merge_and_rebroadcast",
           "sgwu_merge_and_rebroadcast_sharded", "broadcast_tree",
           "agwu_gamma", "agwu_update", "agwu_update_delta", "tree_sub",
           "tree_add_scaled"]


def tree_sub(a, b):
    """a - b, leafwise."""
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_add_scaled(base, delta, scale):
    """base + scale * delta, leafwise (scale is a scalar)."""
    return jax.tree_util.tree_map(lambda x, d: x + scale * d, base, delta)


@jax.jit
def _weighted_sum(stacked, weights):
    """sum_j stacked[j] * weights[j] over leading axis, leafwise."""
    def per_leaf(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(leaf * w, axis=0)
    return jax.tree_util.tree_map(per_leaf, stacked)


# The node-stacked round result is consumed by the merge, and the merged
# weights are immediately re-broadcast for the next round's stack — fusing
# the two lets XLA alias the donated input stack with the output stack
# (identical shapes), so the m× parameter payload is reused, not copied.
@functools.partial(jax.jit, donate_argnums=(0,))
def _merge_and_rebroadcast(stacked, weights):
    merged = _weighted_sum(stacked, weights)
    new_stacked = jax.tree_util.tree_map(
        lambda m, s: jnp.broadcast_to(m[None], s.shape), merged, stacked)
    return merged, new_stacked


@functools.partial(jax.jit, static_argnums=(1,))
def _merge_weights_jit(q, num_nodes: int):
    total = jnp.sum(q)
    # guard: all-zero accuracies degrade to the uniform average
    return jnp.where(total > 0, q / jnp.maximum(total, 1e-12),
                     jnp.full_like(q, 1.0 / num_nodes))


def _merge_weights(accuracies, num_nodes: int):
    """Eq. (7) weighting Q_j / sum_k Q_k, with the all-zero guard.

    Host accuracies are placed explicitly and the arithmetic runs under
    jit, where the scalar guards are trace-time constants — eager ops
    mixing device arrays with python scalars would upload the scalars
    implicitly and trip the sanitizer's transfer guard.
    """
    q = jax.device_put(np.asarray(accuracies, dtype=np.float32))
    return _merge_weights_jit(q, num_nodes)


def _validate_stack(stacked, accuracies) -> int:
    """Shared prologue of the stacked Eq. (7) entry points; returns m."""
    num_nodes = len(accuracies)
    if num_nodes == 0:
        raise ValueError("need at least one local weight set")
    leaves = jax.tree_util.tree_leaves(stacked)
    if leaves and leaves[0].shape[0] != num_nodes:
        raise ValueError(
            f"stacked leading axis {leaves[0].shape[0]} != "
            f"{num_nodes} accuracies")
    return num_nodes


def sgwu_merge_stacked(stacked, accuracies):
    """Eq. (7) against the node-stacked representation.

    ``stacked`` is one pytree whose leaves carry a leading node axis of
    size m (worker j's weights at index j).
    """
    num_nodes = _validate_stack(stacked, accuracies)
    return _weighted_sum(stacked, _merge_weights(accuracies, num_nodes))


def sgwu_merge_and_rebroadcast(stacked, accuracies):
    """Eq. (7) merge plus the next round's replica stack, in one jit.

    Returns ``(merged, new_stacked)``.  ``stacked`` is DONATED — its
    buffers become ``new_stacked`` — so callers must not reuse it.
    """
    num_nodes = _validate_stack(stacked, accuracies)
    return _merge_and_rebroadcast(stacked,
                                  _merge_weights(accuracies, num_nodes))


# ----------------------------------------------------------------------
# Device-sharded Eq. (7): the node axis lives on a real mesh axis and the
# merge is a weighted all-reduce — no device gathers the m-stack.  The
# psum is restricted to the ``nodes`` axis by name, so on a 2-D hybrid
# ``(nodes, model)`` mesh the merge never crosses the inner-layer axis:
# in_spec P("nodes") leaves the stack replicated over ``model`` and each
# model replica runs the identical nodes-collective (§3 composes with §4
# without interfering — see core.planner).
# ----------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _sharded_merge_fn(mesh):
    """Per-mesh jitted shard_map merge: each device holds its node block
    of the stack, contributes w_j * W_j to a psum over the ``nodes`` axis,
    and writes the merged result back into its (donated) block — the
    rebroadcast IS the all-reduce output, so the global weights never
    funnel through a single device."""
    from jax.experimental.shard_map import shard_map
    P = jax.sharding.PartitionSpec

    def body(stacked, weights):
        idx = jax.lax.axis_index("nodes")

        def merge_leaf(x):
            k = x.shape[0]                    # node block size (m / devices)
            w = jax.lax.dynamic_slice_in_dim(weights, idx * k, k)
            w = w.reshape((k,) + (1,) * (x.ndim - 1)).astype(x.dtype)
            return jax.lax.psum(jnp.sum(x * w, axis=0), "nodes")

        merged = jax.tree_util.tree_map(merge_leaf, stacked)
        new_stacked = jax.tree_util.tree_map(
            lambda mg, s: jnp.broadcast_to(mg[None], s.shape), merged,
            stacked)
        return merged, new_stacked

    sm = shard_map(body, mesh=mesh, in_specs=(P("nodes"), P()),
                   out_specs=(P(), P("nodes")))
    return jax.jit(sm, donate_argnums=(0,))


def sgwu_merge_and_rebroadcast_sharded(stacked, accuracies, mesh):
    """Eq. (7) as an on-device weighted all-reduce over a ``nodes`` mesh.

    ``stacked`` is the node-stacked pytree placed with
    ``NamedSharding(mesh, P("nodes"))`` (node j's weights resident on
    device j); its buffers are DONATED.  Returns ``(merged, new_stacked)``
    where ``merged`` is replicated across the mesh (never pulled to host)
    and ``new_stacked`` is the next round's sharded replica stack.
    """
    num_nodes = _validate_stack(stacked, accuracies)
    if num_nodes % mesh.shape["nodes"] != 0:
        raise ValueError(
            f"{num_nodes} nodes do not divide the `nodes` mesh axis "
            f"({mesh.shape['nodes']})")
    return _sharded_merge_fn(mesh)(stacked,
                                   _merge_weights(accuracies, num_nodes))


def sgwu_merge(local_weights: Sequence, accuracies: Sequence[float]):
    """Eq. (7): W(i) = sum_j W_j(i-1) * Q_j / sum_k Q_k.

    ``local_weights`` is a list of pytrees with identical structure.
    """
    if len(local_weights) == 0:
        raise ValueError("need at least one local weight set")
    if len(local_weights) != len(accuracies):
        raise ValueError("one accuracy per local weight set")
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0),
                                     *local_weights)
    return sgwu_merge_stacked(stacked, accuracies)


@functools.partial(jax.jit, static_argnames=("num_nodes",))
def broadcast_tree(tree, num_nodes: int):
    """Replicate a pytree along a new leading node axis of size m."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (num_nodes,) + x.shape), tree)


def agwu_gamma(base_version: int, latest_version: int,
               outstanding_versions: Sequence[int]) -> float:
    """Eq. (9): time-attenuation factor.

    gamma_j(k) = e^{k/(i-1)} / sum_{j'} e^{k'/(i-1)}

    ``base_version`` is k (the global version the submitting node trained
    from); ``latest_version`` is i-1 (the server's current version);
    ``outstanding_versions`` are the base versions k' of the other nodes'
    in-flight local weight sets (the paper's denominator sums over all
    W_{j'}^{k'}, j' != j).  The submitter's own term is included so the
    factor is a proper share in [0, 1] even when it is the only one in
    flight (denominator then equals the numerator => gamma = 1).

    Pure Python/``math`` on purpose: this runs on the host once per AGWU
    push, and the previous ``jnp.exp`` version paid a device round-trip
    (plus f32 rounding) per push inside the event loop.
    """
    denom_versions = list(outstanding_versions) + [base_version]
    i_minus_1 = max(latest_version, 1)
    num = math.exp(base_version / i_minus_1)
    den = sum(math.exp(v / i_minus_1) for v in denom_versions)
    return num / den


def _agwu_apply_impl(global_w, local_w, base_w, scale):
    return jax.tree_util.tree_map(
        lambda g, lw, b: g + scale * (lw - b), global_w, local_w, base_w)


_agwu_apply = jax.jit(_agwu_apply_impl)
# Donated variant for the ParameterServer push path: the submitted local
# weights are consumed by the push (the worker immediately re-pulls), so
# their buffers are reused for the new global weights.  global/base are NOT
# donated — right after a pull they alias each other.
_agwu_apply_donated = jax.jit(_agwu_apply_impl, donate_argnums=(1,))


@jax.jit
def _agwu_apply_delta(global_w, delta, scale):
    return jax.tree_util.tree_map(lambda g, d: g + scale * d,
                                  global_w, delta)


def agwu_update_delta(global_weights, delta, gamma: float, accuracy: float):
    """Eq. (10) from a precomputed node-resident delta W_j(k) - W(k).

    The device-sharded outer layer computes ``delta`` on the submitting
    node's device and ships ONLY the delta to the server device — the
    same float ops (and therefore bit-identical results) as
    ``agwu_update``, split at the subtraction.
    """
    scale = jax.device_put(np.float32(gamma * accuracy))
    return _agwu_apply_delta(global_weights, delta, scale)


def agwu_update(global_weights, local_weights, base_weights,
                gamma: float, accuracy: float, *, donate_local: bool = False):
    """Eq. (10): W(i) = W(i-1) + gamma * Q * (W_j(k) - W(k)).

    ``base_weights`` is the snapshot W(k) the worker trained from.  With
    ``donate_local=True`` the caller hands over ``local_weights``' buffers
    (the ParameterServer push path does).
    """
    # explicit placement: jnp.asarray of a host scalar dispatches an
    # implicit upload and would trip the sanitizer's transfer guard
    scale = jax.device_put(np.float32(gamma * accuracy))
    if donate_local:
        # Donation needs device-committed jax.Arrays (numpy trees from the
        # simulators can't donate and would warn), and XLA rejects donating
        # a buffer that another argument aliases (a worker pushing back an
        # untouched pull) — identity-check the leaves.
        leaves = set(map(id, jax.tree_util.tree_leaves(global_weights)))
        leaves |= set(map(id, jax.tree_util.tree_leaves(base_weights)))
        donate_local = all(isinstance(x, jax.Array) and id(x) not in leaves
                           for x in jax.tree_util.tree_leaves(local_weights))
    apply = _agwu_apply_donated if donate_local else _agwu_apply
    return apply(global_weights, local_weights, base_weights, scale)
