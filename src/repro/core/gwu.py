"""Global Weight Updating strategies — SGWU (Eq. 7) and AGWU (Eq. 9-10).

Both operate on arbitrary JAX pytrees so the same code path serves the
paper's CNN and every assigned LLM architecture.  The update math is jitted;
the versioning/bookkeeping lives in ``param_server.ParameterServer``.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = ["sgwu_merge", "agwu_gamma", "agwu_update", "tree_sub", "tree_add_scaled"]


def tree_sub(a, b):
    """a - b, leafwise."""
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_add_scaled(base, delta, scale):
    """base + scale * delta, leafwise (scale is a scalar)."""
    return jax.tree_util.tree_map(lambda x, d: x + scale * d, base, delta)


@functools.partial(jax.jit, static_argnames=())
def _weighted_sum(stacked, weights):
    """sum_j stacked[j] * weights[j] over leading axis, leafwise."""
    def per_leaf(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(leaf * w, axis=0)
    return jax.tree_util.tree_map(per_leaf, stacked)


def sgwu_merge(local_weights: Sequence, accuracies: Sequence[float]):
    """Eq. (7): W(i) = sum_j W_j(i-1) * Q_j / sum_k Q_k.

    ``local_weights`` is a list of pytrees with identical structure.
    """
    if len(local_weights) == 0:
        raise ValueError("need at least one local weight set")
    if len(local_weights) != len(accuracies):
        raise ValueError("one accuracy per local weight set")
    q = jnp.asarray(accuracies, dtype=jnp.float32)
    total = jnp.sum(q)
    # guard: all-zero accuracies degrade to the uniform average
    w = jnp.where(total > 0, q / jnp.maximum(total, 1e-12),
                  jnp.full_like(q, 1.0 / len(accuracies)))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0),
                                     *local_weights)
    return _weighted_sum(stacked, w)


def agwu_gamma(base_version: int, latest_version: int,
               outstanding_versions: Sequence[int]) -> float:
    """Eq. (9): time-attenuation factor.

    gamma_j(k) = e^{k/(i-1)} / sum_{j'} e^{k'/(i-1)}

    ``base_version`` is k (the global version the submitting node trained
    from); ``latest_version`` is i-1 (the server's current version);
    ``outstanding_versions`` are the base versions k' of the other nodes'
    in-flight local weight sets (the paper's denominator sums over all
    W_{j'}^{k'}, j' != j).  The submitter's own term is included so the
    factor is a proper share in [0, 1] even when it is the only one in
    flight (denominator then equals the numerator => gamma = 1).
    """
    denom_versions = list(outstanding_versions) + [base_version]
    i_minus_1 = max(latest_version, 1)
    num = float(jnp.exp(base_version / i_minus_1))
    den = float(sum(jnp.exp(v / i_minus_1) for v in denom_versions))
    return num / den


@jax.jit
def _agwu_apply(global_w, local_w, base_w, scale):
    return jax.tree_util.tree_map(
        lambda g, l, b: g + scale * (l - b), global_w, local_w, base_w)


def agwu_update(global_weights, local_weights, base_weights,
                gamma: float, accuracy: float):
    """Eq. (10): W(i) = W(i-1) + gamma * Q * (W_j(k) - W(k)).

    ``base_weights`` is the snapshot W(k) the worker trained from.
    """
    scale = jnp.asarray(gamma * accuracy, dtype=jnp.float32)
    return _agwu_apply(global_weights, local_weights, base_weights, scale)
