"""Incremental Data Partitioning and Allocation (IDPA) — Algorithm 3.1.

Faithful implementation of the paper's heterogeneity-aware partitioner
(Eq. 2-6) plus the UDPA baseline used in Fig. 14.

The partitioner is pure Python/NumPy state machine: it consumes *measured*
per-node iteration durations and emits the per-node sample counts for each
allocation batch.  The same object drives (a) the event-driven cluster
simulator, (b) the real BPT trainer (where "nodes" are data-parallel mesh
groups and durations are measured step times), and (c) the dry-run batch
sharding rules.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "IDPAPartitioner",
    "UDPAPartitioner",
    "effective_iterations",
    "workload_balance_degree",
]


def effective_iterations(K: int, A: int) -> int:
    """Eq. (6): remaining iterations after incremental allocation.

    Total K' = A + floor((N*K - N(A+1)/2) / N) = K + A/2 - 1 (paper's Eq. 6,
    integer arithmetic with the floor kept explicit).
    """
    if A < 1:
        raise ValueError("A must be >= 1")
    if A >= K:
        raise ValueError("paper requires A < K (fewer batches than iterations)")
    delta_k = (2 * K - (A + 1)) // 2  # floor(K - (A+1)/2)
    return A + delta_k


def workload_balance_degree(loads: Sequence[float]) -> float:
    """Workload balance metric used for Fig. 15(b): min/max load ratio.

    1.0 = perfectly balanced.  Empty or all-zero loads => 1.0 by convention.
    """
    arr = np.asarray(loads, dtype=np.float64)
    if arr.size == 0 or float(arr.max()) == 0.0:
        return 1.0
    return float(arr.min() / arr.max())


@dataclasses.dataclass
class _BaseAllocator:
    """Shared bookkeeping for IDPA/UDPA."""

    num_samples: int          # N
    num_nodes: int            # m
    num_batches: int          # A

    def __post_init__(self) -> None:
        if self.num_nodes < 1:
            raise ValueError("need at least one computing node")
        if self.num_batches < 1:
            raise ValueError("need at least one allocation batch")
        if self.num_samples < self.num_nodes:
            raise ValueError("need at least one sample per node")
        # cumulative totals n_j = sum_a n_j^(a)
        self.totals = np.zeros(self.num_nodes, dtype=np.int64)
        self.history: list[np.ndarray] = []   # per-batch allocations
        self._batch = 0

    @property
    def batch_size(self) -> int:
        """floor(N/A): samples released per allocation batch."""
        return self.num_samples // self.num_batches

    @property
    def current_batch(self) -> int:
        return self._batch

    @property
    def done(self) -> bool:
        return self._batch >= self.num_batches

    def _record(self, alloc: np.ndarray) -> np.ndarray:
        alloc = alloc.astype(np.int64)
        self.totals += alloc
        self.history.append(alloc)
        self._batch += 1
        return alloc


@dataclasses.dataclass
class IDPAPartitioner(_BaseAllocator):
    """Algorithm 3.1 — heterogeneity-aware incremental partitioner.

    Parameters
    ----------
    frequencies : nominal per-node compute power mu_j (CPU/GPU frequency in
        the paper; measured tokens/s for a TPU data-parallel group here).
    """

    frequencies: Sequence[float] = ()
    # "paper": verbatim Eq. (3)-(5) — T_a from the *arithmetic* mean t_bar,
    #   node m absorbs the remainder.  Faithful, but the arithmetic mean
    #   over-allocates the head nodes on strongly heterogeneous clusters.
    # "balanced": beyond-paper fix — pick the target duration so the batch's
    #   increments sum exactly to floor(N/A) (harmonic-mean form), which
    #   achieves the paper's *stated* objective (all nodes finish together).
    mode: str = "paper"

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.mode not in ("paper", "balanced"):
            raise ValueError(self.mode)
        freq = np.asarray(self.frequencies, dtype=np.float64)
        if freq.shape != (self.num_nodes,):
            raise ValueError("need one frequency per node")
        if np.any(freq <= 0):
            raise ValueError("frequencies must be positive")
        self.freq = freq
        # measured mean per-sample time t_bar_j (populated after batch 1)
        self.per_sample_time = np.zeros(self.num_nodes, dtype=np.float64)

    # ------------------------------------------------------------------
    def first_batch(self) -> np.ndarray:
        """Eq. (2): frequency-proportional split of the first batch."""
        if self._batch != 0:
            raise RuntimeError("first_batch() already consumed")
        b = self.batch_size
        alloc = np.floor(b * self.freq / self.freq.sum()).astype(np.int64)
        # node m takes the remainder (paper's j == m case)
        alloc[-1] = b - int(alloc[:-1].sum())
        return self._record(alloc)

    def next_batch(self, durations: Sequence[float]) -> np.ndarray:
        """Eq. (3)-(5): allocation from measured durations of the previous
        iteration.

        durations[j] = T_j, wall time node j took to process its *current
        total* sample count in the last iteration.
        """
        if self._batch == 0:
            raise RuntimeError("call first_batch() first")
        if self.done:
            raise RuntimeError("all batches already allocated")
        T = np.asarray(durations, dtype=np.float64)
        if T.shape != (self.num_nodes,):
            raise ValueError("need one duration per node")
        if np.any(T <= 0):
            raise ValueError("durations must be positive")

        # t_bar_j = T_j / n_j  (paper normalises by the node's sample count)
        n_now = np.maximum(self.totals, 1)
        t_bar = T / n_now
        self.per_sample_time = t_bar
        t_mean = t_bar.mean()                      # t_bar in Eq. (3)

        a = self._batch + 1                         # 1-indexed batch number
        b = self.batch_size
        if self.mode == "paper":
            # Eq. (3): predicted mean duration of iteration a
            T_a = (b * a * t_mean) / self.num_nodes
        else:
            # balanced: duration such that sum_j T_a/t_j == b*a exactly
            T_a = (b * a) / float(np.sum(1.0 / t_bar))
        # Eq. (4): target cumulative sample count so all nodes finish at T_a
        n_target = T_a / t_bar
        # Eq. (5): the increment this batch, floored at zero (a node that is
        # already over-subscribed takes no new samples rather than "negative"
        # samples; the paper implicitly assumes non-negative increments).
        inc = np.floor(n_target - self.totals).astype(np.int64)
        inc = np.maximum(inc, 0)
        # node m absorbs the remainder so the batch sums to floor(N/A)
        head = int(inc[:-1].sum())
        if head > b:
            # rescale head nodes to fit the batch, preserving proportions
            scaled = np.floor(inc[:-1] * (b / head)).astype(np.int64)
            inc[:-1] = scaled
            head = int(scaled.sum())
        inc[-1] = b - head
        return self._record(inc)

    def allocate_all(self, duration_fn) -> np.ndarray:
        """Drive all A batches; duration_fn(totals)->durations per node."""
        self.first_batch()
        while not self.done:
            self.next_batch(duration_fn(self.totals))
        return self.totals.copy()


@dataclasses.dataclass
class UDPAPartitioner(_BaseAllocator):
    """Uniform baseline of Fig. 14: equal split, all at once or per batch."""

    def first_batch(self) -> np.ndarray:
        return self.next_batch(None)

    def next_batch(self, _durations=None) -> np.ndarray:
        if self.done:
            raise RuntimeError("all batches already allocated")
        b = self.batch_size
        alloc = np.full(self.num_nodes, b // self.num_nodes, dtype=np.int64)
        alloc[-1] = b - int(alloc[:-1].sum())
        return self._record(alloc)

    def allocate_all(self, duration_fn=None) -> np.ndarray:
        while not self.done:
            self.next_batch(None)
        return self.totals.copy()
