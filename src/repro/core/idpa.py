"""Incremental Data Partitioning and Allocation (IDPA) — Algorithm 3.1.

Faithful implementation of the paper's heterogeneity-aware partitioner
(Eq. 2-6) plus the UDPA baseline used in Fig. 14.

The partitioner is pure Python/NumPy state machine: it consumes *measured*
per-node iteration durations and emits the per-node sample counts for each
allocation batch.  The same object drives (a) the event-driven cluster
simulator, (b) the real BPT trainer (where "nodes" are data-parallel mesh
groups and durations are measured step times), and (c) the dry-run batch
sharding rules.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "IDPAPartitioner",
    "UDPAPartitioner",
    "effective_iterations",
    "workload_balance_degree",
]


def effective_iterations(K: int, A: int) -> int:
    """Eq. (6): remaining iterations after incremental allocation.

    Total K' = A + floor((N*K - N(A+1)/2) / N) = K + A/2 - 1 (paper's Eq. 6,
    integer arithmetic with the floor kept explicit).
    """
    if A < 1:
        raise ValueError("A must be >= 1")
    if A >= K:
        raise ValueError("paper requires A < K (fewer batches than iterations)")
    delta_k = (2 * K - (A + 1)) // 2  # floor(K - (A+1)/2)
    return A + delta_k


def workload_balance_degree(loads: Sequence[float]) -> float:
    """Workload balance metric used for Fig. 15(b): min/max load ratio.

    1.0 = perfectly balanced.  Empty or all-zero loads => 1.0 by convention.
    """
    arr = np.asarray(loads, dtype=np.float64)
    if arr.size == 0 or float(arr.max()) == 0.0:
        return 1.0
    return float(arr.min() / arr.max())


@dataclasses.dataclass
class _BaseAllocator:
    """Shared bookkeeping for IDPA/UDPA."""

    num_samples: int          # N
    num_nodes: int            # m
    num_batches: int          # A

    def __post_init__(self) -> None:
        if self.num_nodes < 1:
            raise ValueError("need at least one computing node")
        if self.num_batches < 1:
            raise ValueError("need at least one allocation batch")
        if self.num_samples < self.num_nodes:
            raise ValueError("need at least one sample per node")
        # cumulative totals n_j = sum_a n_j^(a)
        self.totals = np.zeros(self.num_nodes, dtype=np.int64)
        self.history: list[np.ndarray] = []   # per-batch allocations
        self._batch = 0

    @property
    def batch_size(self) -> int:
        """floor(N/A): samples released per allocation batch."""
        return self.num_samples // self.num_batches

    @property
    def current_batch(self) -> int:
        return self._batch

    @property
    def done(self) -> bool:
        return self._batch >= self.num_batches

    def _record(self, alloc: np.ndarray) -> np.ndarray:
        alloc = alloc.astype(np.int64)
        self.totals += alloc
        self.history.append(alloc)
        self._batch += 1
        return alloc

    def _active_mask(self, active) -> np.ndarray:
        """Validate/default the churn mask: allocation only targets nodes
        the fault schedule reports alive.  Dead nodes keep what they were
        already allocated (§3.3.1: no migration) but the current batch is
        distributed entirely among the active nodes — the round is never
        starved."""
        if active is None:
            return np.ones(self.num_nodes, dtype=bool)
        mask = np.asarray(active, dtype=bool)
        if mask.shape != (self.num_nodes,):
            raise ValueError("need one active flag per node")
        if not mask.any():
            raise ValueError(
                "cannot allocate a batch with every node inactive")
        return mask

    # ------------------------------------------------------------------
    # crash-safe checkpointing: the partitioner is part of the resumable
    # training state (a resumed run must continue the SAME incremental
    # allocation, not restart it)
    def state_dict(self) -> dict:
        return {
            "totals": self.totals.tolist(),
            "history": [h.tolist() for h in self.history],
            "batch": self._batch,
        }

    def load_state_dict(self, state: dict) -> None:
        totals = np.asarray(state["totals"], dtype=np.int64)
        if totals.shape != (self.num_nodes,):
            raise ValueError(
                f"partitioner state has {totals.shape[0]} nodes, "
                f"expected {self.num_nodes}")
        self.totals = totals
        self.history = [np.asarray(h, dtype=np.int64)
                        for h in state["history"]]
        self._batch = int(state["batch"])


@dataclasses.dataclass
class IDPAPartitioner(_BaseAllocator):
    """Algorithm 3.1 — heterogeneity-aware incremental partitioner.

    Parameters
    ----------
    frequencies : nominal per-node compute power mu_j (CPU/GPU frequency in
        the paper; measured tokens/s for a TPU data-parallel group here).
    """

    frequencies: Sequence[float] = ()
    # "paper": verbatim Eq. (3)-(5) — T_a from the *arithmetic* mean t_bar,
    #   node m absorbs the remainder.  Faithful, but the arithmetic mean
    #   over-allocates the head nodes on strongly heterogeneous clusters.
    # "balanced": beyond-paper fix — pick the target duration so the batch's
    #   increments sum exactly to floor(N/A) (harmonic-mean form), which
    #   achieves the paper's *stated* objective (all nodes finish together).
    mode: str = "paper"

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.mode not in ("paper", "balanced"):
            raise ValueError(self.mode)
        freq = np.asarray(self.frequencies, dtype=np.float64)
        if freq.shape != (self.num_nodes,):
            raise ValueError("need one frequency per node")
        if np.any(freq <= 0):
            raise ValueError("frequencies must be positive")
        self.freq = freq
        # measured mean per-sample time t_bar_j (populated after batch 1)
        self.per_sample_time = np.zeros(self.num_nodes, dtype=np.float64)

    # ------------------------------------------------------------------
    def first_batch(self, active=None) -> np.ndarray:
        """Eq. (2): frequency-proportional split of the first batch.

        ``active`` masks nodes out of the allocation (node churn): the
        batch is split among the active nodes only.
        """
        if self._batch != 0:
            raise RuntimeError("first_batch() already consumed")
        mask = self._active_mask(active)
        b = self.batch_size
        freq = np.where(mask, self.freq, 0.0)
        alloc = np.floor(b * freq / freq.sum()).astype(np.int64)
        # the last active node takes the remainder (paper's j == m case)
        last = int(np.flatnonzero(mask)[-1])
        alloc[last] = b - int(alloc.sum() - alloc[last])
        return self._record(alloc)

    def next_batch(self, durations: Sequence[float],
                   active=None) -> np.ndarray:
        """Eq. (3)-(5): allocation from measured durations of the previous
        iteration.

        durations[j] = T_j, wall time node j took to process its *current
        total* sample count in the last iteration.  Churn extensions:

        * ``active`` masks failed nodes out of the batch entirely (their
          duration entries are ignored — a dead node reports nothing);
        * an active node may report ``inf`` (zero capacity): it receives
          zero new samples, and the batch is still fully distributed among
          the finite-capacity nodes — no starvation, no crash.
        """
        if self._batch == 0:
            raise RuntimeError("call first_batch() first")
        if self.done:
            raise RuntimeError("all batches already allocated")
        mask = self._active_mask(active)
        T = np.asarray(durations, dtype=np.float64)
        if T.shape != (self.num_nodes,):
            raise ValueError("need one duration per node")
        if np.any(T[mask] <= 0) or np.any(np.isnan(T[mask])):
            raise ValueError("durations must be positive")

        # t_bar_j = T_j / n_j  (paper normalises by the node's sample count)
        n_now = np.maximum(self.totals, 1)
        t_bar = np.where(mask, T / n_now, np.inf)
        # capacity carriers: active nodes with finite measured time.  An
        # active node at zero capacity (inf duration) stays in the run but
        # takes no new work this batch.
        carrier = mask & np.isfinite(t_bar)
        if not carrier.any():
            raise ValueError(
                "every active node reported infinite duration — no node "
                "can carry this allocation batch")
        self.per_sample_time = np.where(carrier, T / n_now,
                                        self.per_sample_time)
        t_mean = t_bar[carrier].mean()             # t_bar in Eq. (3)

        a = self._batch + 1                         # 1-indexed batch number
        b = self.batch_size
        if self.mode == "paper":
            # Eq. (3): predicted mean duration of iteration a (the node
            # count is the carriers' — the batch only lands on them)
            T_a = (b * a * t_mean) / int(carrier.sum())
        else:
            # balanced: duration such that sum_j T_a/t_j == b*a exactly
            T_a = (b * a) / float(np.sum(1.0 / t_bar[carrier]))
        # Eq. (4): target cumulative sample count so all nodes finish at T_a
        with np.errstate(invalid="ignore"):
            n_target = np.where(carrier, T_a / t_bar, 0.0)
        # Eq. (5): the increment this batch, floored at zero (a node that is
        # already over-subscribed takes no new samples rather than "negative"
        # samples; the paper implicitly assumes non-negative increments).
        inc = np.floor(n_target - self.totals).astype(np.int64)
        inc = np.maximum(inc, 0)
        inc[~carrier] = 0
        # the last capacity-carrying node absorbs the remainder so the
        # batch sums to floor(N/A)
        last = int(np.flatnonzero(carrier)[-1])
        head = int(inc.sum() - inc[last])
        if head > b:
            # rescale head nodes to fit the batch, preserving proportions
            scale = b / head
            inc = np.floor(inc * scale).astype(np.int64)
            inc[~carrier] = 0
            head = int(inc.sum() - inc[last])
        inc[last] = b - head
        return self._record(inc)

    def allocate_all(self, duration_fn) -> np.ndarray:
        """Drive all A batches; duration_fn(totals)->durations per node."""
        self.first_batch()
        while not self.done:
            self.next_batch(duration_fn(self.totals))
        return self.totals.copy()

    def state_dict(self) -> dict:
        state = super().state_dict()
        state["per_sample_time"] = self.per_sample_time.tolist()
        return state

    def load_state_dict(self, state: dict) -> None:
        super().load_state_dict(state)
        self.per_sample_time = np.asarray(state["per_sample_time"],
                                          dtype=np.float64)


@dataclasses.dataclass
class UDPAPartitioner(_BaseAllocator):
    """Uniform baseline of Fig. 14: equal split, all at once or per batch."""

    def first_batch(self, active=None) -> np.ndarray:
        return self.next_batch(None, active=active)

    def next_batch(self, _durations=None, active=None) -> np.ndarray:
        if self.done:
            raise RuntimeError("all batches already allocated")
        mask = self._active_mask(active)
        b = self.batch_size
        k = int(mask.sum())
        alloc = np.where(mask, b // k, 0).astype(np.int64)
        last = int(np.flatnonzero(mask)[-1])
        alloc[last] = b - int(alloc.sum() - alloc[last])
        return self._record(alloc)

    def allocate_all(self, duration_fn=None) -> np.ndarray:
        while not self.done:
            self.next_batch(None)
        return self.totals.copy()
