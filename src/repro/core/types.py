"""Shared configuration dataclasses for models, shapes and training."""
from __future__ import annotations

import dataclasses

__all__ = ["ModelConfig", "ShapeConfig", "TrainConfig",
           "OUTER_STRATEGIES", "PARTITIONINGS", "OPTIMIZERS"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Every assigned config cites its source in
    ``src/repro/configs/<id>.py``."""

    name: str
    arch_type: str                 # dense|moe|ssm|hybrid|encdec|vlm|audio|cnn
    num_layers: int
    d_model: int
    num_heads: int = 0             # 0 => attention-free
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0           # per-expert FFN width (moe_intermediate)
    moe_capacity_factor: float = 1.25
    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0             # N: state size per head
    ssm_heads: int = 0             # H: number of SSD heads
    ssm_head_dim: int = 0          # P: channels per head
    ssm_expand: int = 2
    conv_kernel: int = 4
    # --- attention details ---
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # 0 => full attention
    window_pattern: int = 0        # gemma2: every `pattern`-th layer global
    global_layers: tuple = ()      # hymba: explicit full-attention layer ids
    attn_softcap: float = 0.0      # gemma2 logit soft-capping (attn)
    final_softcap: float = 0.0     # gemma2 final-logit soft-capping
    post_norm: bool = False        # gemma2 post-block norms
    qk_norm: bool = False          # qwen3 per-head q/k RMSNorm
    activation: str = "silu"       # silu | gelu
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # --- enc-dec ---
    num_encoder_layers: int = 0
    # --- multimodal stub frontend ---
    frontend: str = ""             # "" | "audio" | "vision"
    num_frontend_tokens: int = 0   # patches / frames prepended to the text
    # --- kernel/blocking knobs (0 = module default; also used by the
    #     dry-run cost calibration, which sets chunk = seq to remove
    #     inner loops so HLO cost analysis counts every op) ---
    attn_q_chunk: int = 0
    attn_k_chunk: int = 0
    ce_chunk: int = 0
    ssd_chunk: int = 0
    # --- beyond-paper optimization knobs (§Perf; defaults = baseline) ---
    bf16_params_compute: bool = False  # cast layer params to bf16 in-graph
    mlp_megatron: bool = False         # AG(x)+RS(y) MLP instead of FSDP-ish
    embed_reshard: bool = False        # d-shard the embed table pre-lookup
    attn_kv_gather: bool = False       # q/out stay seq-sharded; gather K/V
    embed_onehot: bool = False         # one-hot matmul embedding (TPU-style)
    attn_block_skip: bool = False      # lax.cond-skip masked-out kv blocks
    # --- misc ---
    dtype: str = "bfloat16"        # activation dtype
    param_dtype: str = "float32"
    citation: str = ""

    # ------------------------------------------------------------------
    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width (H * P)."""
        return self.ssm_heads * self.ssm_head_dim

    def param_count(self) -> int:
        """Approximate parameter count N (for 6·N·D model-FLOPs)."""
        d, L = self.d_model, self.num_layers
        total = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
        per_layer = 0
        if self.num_heads:
            per_layer += d * self.attn_dim + 2 * d * self.kv_dim \
                + self.attn_dim * d
        if self.num_experts:
            per_layer += self.num_experts * 3 * d * self.expert_d_ff \
                + d * self.num_experts
        elif self.d_ff:
            per_layer += 3 * d * self.d_ff    # gated MLP: wi, wg, wo
        if self.arch_type in ("ssm", "hybrid"):
            di, G, N, H = self.d_inner, 1, self.ssm_state, self.ssm_heads
            proj = 2 * di + 2 * G * N + H
            per_layer += d * proj + di * d + di  # in_proj, out_proj, skip D
        total += L * per_layer
        if self.num_encoder_layers:
            enc_per = d * self.attn_dim * 2 + 2 * d * self.kv_dim \
                + 3 * d * self.d_ff
            total += self.num_encoder_layers * enc_per
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.num_experts:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        dense = self.param_count() - L * self.num_experts * 3 * d * \
            self.expert_d_ff
        return int(dense + L * self.top_k * 3 * d * self.expert_d_ff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # "train" | "prefill" | "decode"


OUTER_STRATEGIES = ("sgwu", "agwu", "sync")
PARTITIONINGS = ("idpa", "udpa")
OPTIMIZERS = ("sgd", "momentum", "adamw")


@dataclasses.dataclass
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    optimizer: str = "adamw"       # sgd | momentum | adamw
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    seed: int = 0
    # --- BPT outer layer ---
    outer_strategy: str = "agwu"   # sgwu | agwu | sync (plain data parallel)
    partitioning: str = "idpa"     # idpa | udpa
    outer_nodes: int = 4           # virtual computing nodes (DP groups)
    allocation_batches: int = 4    # A in Alg. 3.1
    local_steps: int = 1           # h: inner steps between merges (agwu)
    remat: bool = False
    # Fuse the m-node outer layer into ONE vmapped+scanned jitted dispatch
    # per SGWU round (node-stacked params/opt-states) instead of the
    # sequential per-node Python loop.  False keeps the legacy loop — the
    # numerical-equivalence regression tests and the outer_loop benchmark
    # compare the two.  AGWU is unaffected (its event order IS the
    # algorithm).
    fused_outer: bool = True
    # --- device-sharded outer layer ---
    # Place the node axis on a real device mesh (launch/mesh.py `nodes`
    # family): each computing node's params/opt-state/batches live on its
    # own device, the nodes x local_steps grid runs under shard_map, and
    # the SGWU merge is an on-device weighted all-reduce (psum).  Falls
    # back transparently to the fused vmap emulation when fewer than
    # ``outer_nodes`` devices exist.  AGWU places each node's weights on
    # its device and pushes device-resident deltas.
    device_outer: bool = False
    # Named mesh from launch.mesh.MESHES to place the node axis on ("" =
    # auto 1-D `nodes` mesh over the first ``outer_nodes`` devices).  The
    # mesh must expose a `nodes` axis of size ``outer_nodes``.  A 2-D
    # `nodesNxmodelK` hybrid mesh additionally turns on the per-layer
    # inner-parallelism planner (core.planner) over the `model` axis.
    mesh_name: str = ""
    # IDPA heterogeneity in the round data: per-node effective batch sizes
    # proportional to the current allocation, realized as padded+masked
    # stripes so slow nodes/devices carry smaller effective loads while
    # every stripe keeps the static (B, ...) shape the fused/sharded round
    # needs.  The loss_fn must honour an optional batch["mask"].
    uneven_batches: bool = False

    def __post_init__(self):
        """Choice-set validation: a typo'd strategy/partitioning/optimizer
        fails at construction with one canonical message instead of
        mid-train.  Flag-COMBINATION rules (uneven_batches x strategy,
        device/mesh resolution, fallbacks) live in one place —
        ``repro.core.engine.resolve_engine`` — so a config that needs
        runtime context (device counts) still fails there, before any
        training work, with the same message everywhere."""
        for field, value, allowed in (
                ("outer_strategy", self.outer_strategy, OUTER_STRATEGIES),
                ("partitioning", self.partitioning, PARTITIONINGS),
                ("optimizer", self.optimizer, OPTIMIZERS)):
            if value not in allowed:
                raise ValueError(
                    f"TrainConfig.{field}={value!r}: choose one of "
                    f"{allowed}")
        if self.outer_nodes < 1:
            raise ValueError(
                f"TrainConfig.outer_nodes={self.outer_nodes}: need >= 1")
        if self.local_steps < 1:
            raise ValueError(
                f"TrainConfig.local_steps={self.local_steps}: need >= 1")
