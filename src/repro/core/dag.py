"""Inner-layer task decomposition and priority scheduling (§4, Alg. 4.2).

The paper decomposes a CNN subnetwork's training step into a task DAG
(per-output-element convolution tasks, per-layer loss tasks, per-filter
gradient tasks), marks level-based priorities (upstream > downstream,
same level = same priority) and list-schedules onto threads, picking the
least-loaded thread for each ready task.

On TPU the *executed* analogue is the Pallas grid (see kernels/); this module
keeps the literal scheduler for fidelity experiments: it reproduces the
paper's thread-level load-balance / critical-path-waiting metrics (Fig. 10,
Fig. 14d).
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "Task", "TaskDAG", "conv_layer_tasks", "cnn_training_dag",
    "priority_schedule", "ScheduleResult", "conv_output_shape",
    "conv_grid_tasks", "choose_oc_tile", "fc_grid_tasks", "choose_fc_block",
]


# ----------------------------------------------------------------------
# Task DAG
# ----------------------------------------------------------------------
@dataclasses.dataclass
class Task:
    tid: int
    name: str
    cost: float                      # execution duration estimate
    deps: tuple = ()                 # tids this task waits on
    level: int = 0                   # DAG level (entrance = 0)
    priority: int = 0                # higher runs earlier


class TaskDAG:
    def __init__(self):
        self.tasks: dict[int, Task] = {}
        self._next = 0

    def add(self, name: str, cost: float,
            deps: Iterable[int] = ()) -> int:
        tid = self._next
        self._next += 1
        self.tasks[tid] = Task(tid, name, float(cost), tuple(deps))
        return tid

    def __len__(self) -> int:
        return len(self.tasks)

    # -- priority marking (paper §4.2(1)) -------------------------------
    def mark_priorities(self, max_priority: int = 1_000_000) -> None:
        """Entrance tasks get the maximum value; each level down decrements.

        Upstream tasks' priorities are strictly higher than downstream's;
        tasks at the same level share the same priority.
        """
        # topological levels
        indeg = {t: len(self.tasks[t].deps) for t in self.tasks}
        children: dict[int, list[int]] = {t: [] for t in self.tasks}
        for t in self.tasks.values():
            for d in t.deps:
                children[d].append(t.tid)
        ready = [t for t, d in indeg.items() if d == 0]
        for t in ready:
            self.tasks[t].level = 0
        seen = 0
        queue = list(ready)
        while queue:
            u = queue.pop()
            seen += 1
            for v in children[u]:
                self.tasks[v].level = max(self.tasks[v].level,
                                          self.tasks[u].level + 1)
                indeg[v] -= 1
                if indeg[v] == 0:
                    queue.append(v)
        if seen != len(self.tasks):
            raise ValueError("task graph has a cycle")
        for t in self.tasks.values():
            t.priority = max_priority - t.level

    def critical_path(self) -> float:
        """Longest cost-weighted path (lower bound on makespan)."""
        order = sorted(self.tasks.values(), key=lambda t: t.level)
        finish: dict[int, float] = {}
        for t in order:
            start = max((finish[d] for d in t.deps), default=0.0)
            finish[t.tid] = start + t.cost
        return max(finish.values(), default=0.0)

    def total_work(self) -> float:
        return sum(t.cost for t in self.tasks.values())


# ----------------------------------------------------------------------
# Conv-layer decomposition (Eq. 12-14)
# ----------------------------------------------------------------------
def conv_output_shape(hx: int, wx: int, hf: int, wf: int,
                      stride: int = 1, pad: int = 0) -> tuple[int, int]:
    """Eq. (12): output feature-map height/width."""
    ha = (hx - hf + 2 * pad) // stride + 1
    wa = (wx - wf + 2 * pad) // stride + 1
    if ha <= 0 or wa <= 0:
        raise ValueError("filter larger than padded input")
    return ha, wa


def conv_layer_tasks(dag: TaskDAG, hx: int, wx: int, hf: int, wf: int,
                     stride: int = 1, pad: int = 0,
                     depth: int = 1, deps: Sequence[int] = (),
                     tile: int = 1, name: str = "conv") -> list[int]:
    """Eq. (13): K_C = H_a * W_a independent tasks, one per output element
    (or per `tile`x`tile` block — the BlockSpec analogue).

    Each task's cost = D_f*H_f*W_f multiply-adds per element * elements.
    Returns the created task ids (all mutually independent).
    """
    ha, wa = conv_output_shape(hx, wx, hf, wf, stride, pad)
    per_elem = depth * hf * wf
    tids = []
    for i0 in range(0, ha, tile):
        for j0 in range(0, wa, tile):
            elems = min(tile, ha - i0) * min(tile, wa - j0)
            tids.append(dag.add(f"{name}[{i0}:{j0}]", per_elem * elems, deps))
    return tids


def cnn_training_dag(layer_specs: Sequence[dict], tile: int = 4) -> TaskDAG:
    """Build the full forward+backward task DAG for a CNN (Fig. 9).

    ``layer_specs``: list of {"kind": "conv"|"pool"|"fc", ...dims}.
    Forward tasks chain layer-to-layer; backward tasks mirror them in
    reverse; weight-gradient tasks hang off the backward pass.
    """
    dag = TaskDAG()
    prev: list[int] = []
    fwd_layers: list[list[int]] = []
    for li, spec in enumerate(layer_specs):
        kind = spec["kind"]
        if kind == "conv":
            tids = conv_layer_tasks(
                dag, spec["hx"], spec["wx"], spec["hf"], spec["wf"],
                spec.get("stride", 1), spec.get("pad", 0),
                spec.get("depth", 1), prev, tile, name=f"fwd{li}")
        elif kind == "pool":
            ha, wa = conv_output_shape(spec["hx"], spec["wx"],
                                       spec["k"], spec["k"], spec["k"], 0)
            tids = [dag.add(f"pool{li}", ha * wa, prev)]
        elif kind == "fc":
            # one task per output-neuron block
            blocks = max(1, spec["out"] // max(spec.get("block", 64), 1))
            tids = [dag.add(f"fc{li}[{b}]", spec["in"] * spec["out"] / blocks,
                            prev) for b in range(blocks)]
        else:
            raise ValueError(kind)
        fwd_layers.append(tids)
        prev = tids

    # backward: per-layer error tasks (Eq. 18, parallel over neurons of
    # L_{l-1}) then weight-gradient tasks (Eq. 21, parallel over filters)
    bwd_prev = prev
    for li in range(len(layer_specs) - 1, -1, -1):
        err = [dag.add(f"bwd{li}.err[{b}]",
                       max(1.0, dag.tasks[t].cost * 0.5), bwd_prev)
               for b, t in enumerate(fwd_layers[li][: max(1, len(fwd_layers[li]) // 2)])]
        grad = [dag.add(f"bwd{li}.grad[{b}]",
                        max(1.0, dag.tasks[t].cost * 0.3), err)
                for b, t in enumerate(fwd_layers[li][: max(1, len(fwd_layers[li]) // 4)])]
        bwd_prev = err + grad
    return dag


# ----------------------------------------------------------------------
# Executed-grid decomposition (PT_Conv <-> pallas_call grid)
# ----------------------------------------------------------------------
def conv_grid_tasks(dag: TaskDAG, batch: int, cout: int, oc_tile: int,
                    cost_per_channel: float = 1.0,
                    deps: Sequence[int] = (),
                    name: str = "pt_conv") -> list[int]:
    """The TPU-executed task list: one task per (batch, oc-tile) grid cell.

    This is the paper's PT_Conv expressed at the granularity the Pallas
    kernel actually runs — the grid is (batch, cout/oc_tile), each cell a
    kh*kw-matmul task over one output-channel tile.  All tasks are mutually
    independent; each costs ``oc_tile * cost_per_channel``.
    """
    if oc_tile <= 0 or cout % oc_tile:
        raise ValueError(f"oc_tile {oc_tile} must divide cout {cout}")
    cost = oc_tile * cost_per_channel
    return [dag.add(f"{name}[{b}:{c}]", cost, deps)
            for b in range(batch) for c in range(0, cout, oc_tile)]


@functools.lru_cache(maxsize=None)
def choose_oc_tile(batch: int, cout: int, workers: int = 8,
                   min_tile: int = 8) -> int:
    """Pick the output-channel tile for the executed conv grid (PT_Conv).

    For every candidate tile (divisors of ``cout`` no smaller than
    ``min_tile``, clamped to ``cout``) the candidate task grid is built with
    :func:`conv_grid_tasks` and list-scheduled with Alg. 4.2
    (:func:`priority_schedule`) over ``workers`` threads; the tile with the
    minimal makespan wins, larger tiles breaking ties (fewer, bigger
    MXU-friendly tasks).  Task decomposition and the executed Pallas grid
    stay one concept: the kernels run exactly the grid this model scores.

    ``min_tile`` keeps tiles lane-friendly on TPU — per-filter scalar tasks
    (the paper's CPU/GPU granularity) waste the 128-wide MXU lanes.
    """
    if batch < 1 or cout < 1:
        raise ValueError("batch and cout must be >= 1")
    floor = min(cout, max(1, min_tile))
    best_tile, best_makespan = cout, float("inf")
    for tile in range(cout, floor - 1, -1):
        if cout % tile:
            continue
        dag = TaskDAG()
        conv_grid_tasks(dag, batch, cout, tile)
        makespan = priority_schedule(dag, workers).makespan
        if makespan < best_makespan - 1e-9:
            best_tile, best_makespan = tile, makespan
    return best_tile


def fc_grid_tasks(dag: TaskDAG, d_out: int, block: int,
                  cost_per_neuron: float = 1.0, deps: Sequence[int] = (),
                  name: str = "pt_fc") -> list[int]:
    """The TPU-executed FC task list: one task per output-neuron block.

    This is the paper's §4.1.2 G_FC granularity expressed at the grid the
    Pallas dense kernel actually runs — (d_out/block,), each cell one
    ``(B, Din) x (Din, block)`` matmul task (the whole batch lives in one
    cell, unlike the conv grid's batch axis).  All tasks are mutually
    independent; each costs ``block * cost_per_neuron``.
    """
    if block <= 0 or d_out % block:
        raise ValueError(f"block {block} must divide d_out {d_out}")
    cost = block * cost_per_neuron
    return [dag.add(f"{name}[{n}]", cost, deps)
            for n in range(0, d_out, block)]


@functools.lru_cache(maxsize=None)
def choose_fc_block(d_out: int, workers: int = 8, min_block: int = 8) -> int:
    """Pick the output-neuron block for the executed dense grid (G_FC).

    The ``choose_oc_tile`` sibling for the FC stack: every candidate block
    (divisors of ``d_out`` no smaller than ``min_block``, clamped to
    ``d_out``) builds its task grid with :func:`fc_grid_tasks` and is
    list-scheduled with Alg. 4.2 (:func:`priority_schedule`) over
    ``workers`` threads; the block with the minimal makespan wins, larger
    blocks breaking ties (fewer, bigger MXU-friendly tasks).  The dense
    kernel runs exactly the grid this model scores — decomposition and
    executed grid stay one concept.

    ``min_block`` keeps blocks lane-friendly on TPU — per-neuron scalar
    tasks (the paper's CPU/GPU granularity) waste the 128-wide MXU lanes.
    """
    if d_out < 1:
        raise ValueError("d_out must be >= 1")
    floor = min(d_out, max(1, min_block))
    best_block, best_makespan = d_out, float("inf")
    for block in range(d_out, floor - 1, -1):
        if d_out % block:
            continue
        dag = TaskDAG()
        fc_grid_tasks(dag, d_out, block)
        makespan = priority_schedule(dag, workers).makespan
        if makespan < best_makespan - 1e-9:
            best_block, best_makespan = block, makespan
    return best_block


# ----------------------------------------------------------------------
# Priority list scheduling (Alg. 4.2)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ScheduleResult:
    makespan: float
    thread_busy: np.ndarray            # busy time per thread
    waiting_time: float                # sum of (start - ready) over tasks
    critical_path: float
    balance_degree: float              # min/max busy
    speedup: float                     # total_work / makespan

    def summary(self) -> dict:
        return {
            "makespan": round(self.makespan, 3),
            "waiting": round(self.waiting_time, 3),
            "balance": round(self.balance_degree, 4),
            "speedup": round(self.speedup, 3),
            "cp_bound": round(self.critical_path, 3),
        }


def priority_schedule(dag: TaskDAG, num_threads: int) -> ScheduleResult:
    """Alg. 4.2: order by priority, wait on deps, assign to the thread with
    minimal workload.  Event-driven so waits are exact."""
    if num_threads < 1:
        raise ValueError("need >= 1 thread")
    dag.mark_priorities()
    tasks = dag.tasks
    indeg = {t: len(tasks[t].deps) for t in tasks}
    children: dict[int, list[int]] = {t: [] for t in tasks}
    for t in tasks.values():
        for d in t.deps:
            children[d].append(t.tid)

    ready_time = {t: 0.0 for t in tasks if indeg[t] == 0}
    # ready heap ordered by (-priority, ready_time, tid)  — Alg 4.2 line 1
    ready = [(-tasks[t].priority, 0.0, t) for t in ready_time]
    heapq.heapify(ready)
    thread_free = np.zeros(num_threads)
    busy = np.zeros(num_threads)
    finish: dict[int, float] = {}
    waiting = 0.0

    while ready:
        _, r_time, tid = heapq.heappop(ready)
        k = int(np.argmin(thread_free))           # least-loaded thread
        start = max(thread_free[k], r_time)
        waiting += start - r_time
        end = start + tasks[tid].cost
        thread_free[k] = end
        busy[k] += tasks[tid].cost
        finish[tid] = end
        for v in children[tid]:
            indeg[v] -= 1
            if indeg[v] == 0:
                rt = max(finish[d] for d in tasks[v].deps)
                heapq.heappush(ready, (-tasks[v].priority, rt, v))

    if len(finish) != len(tasks):
        raise RuntimeError("schedule incomplete (cycle?)")
    makespan = max(finish.values(), default=0.0)
    total = dag.total_work()
    mx = float(busy.max()) if busy.size else 1.0
    return ScheduleResult(
        makespan=makespan,
        thread_busy=busy,
        waiting_time=waiting,
        critical_path=dag.critical_path(),
        balance_degree=float(busy.min() / mx) if mx > 0 else 1.0,
        speedup=total / makespan if makespan > 0 else 1.0,
    )
