"""Per-layer parallelization planner for the 2-D ``(nodes, model)`` mesh.

BPT-CNN composes two parallel layers: outer data parallelism across the
m computing nodes (§3, the ``nodes`` mesh axis) and inner task
parallelism within each subnetwork (§4).  Before this module the inner
layer's planning lived in three places that never talked to each other —
the Alg. 4.2 cost model (``core.dag.choose_oc_tile``/``choose_fc_block``)
picked kernel grids, ``launch.sharding`` held path-suffix model-axis
rules, and ``launch.hillclimb`` searched config overrides.  Following
"Exploring Hidden Dimensions in Parallelizing CNNs" (1802.04924, the
per-layer configuration search) and Dryden et al. (1903.06681,
channel/batch partitioning), :func:`plan_network` unifies them: it walks
the CNN layer by layer and emits a :class:`LayerPlan` — parallel
dimension ∈ {batch, channel, replicate} on the ``model`` axis, the
activation ``PartitionSpec``, and the executed kernel tile — scored by
the same roofline terms ``launch.roofline`` charges compiled HLO with.

The plan is not advisory: ``ShardMapEngine`` executes exactly what it
says (the PR 2/5 "scheduled == executed" principle hoisted from kernel
grids up to mesh placement).  The engine enters a :func:`plan_scope`
around the round trace; ``kernels.ops`` consumes each layer's plan via
:func:`take` — the tile knob feeds the Pallas grid, and a ``channel``
fc runs Megatron-style column parallelism built from the three
replication-aware collectives below (:func:`rep_in`, :func:`shard_dim`,
:func:`gather_cols`), whose custom VJPs keep weight gradients exactly
replicated across ``model``.

Two executable plan families (chain-consistent end to end):

- ``batch``:   every layer splits its batch over ``model`` (Dryden's
  strong-scaling axis).  The per-shard loss/grads are recombined by the
  exact sample-count-weighted ``psum`` of :func:`grad_combine` — an
  equality, not an approximation, for any per-example-mean loss
  (including the masked mean of uneven IDPA stripes).
- ``channel``: the batch stays replicated; each fc layer independently
  goes column-parallel over ``model`` when its width divides (1802.04924
  picks per-layer), convs replicate.  All gradient communication is
  induced by the collectives' transposes — no recombine step.

The Eq. 7 merge never changes: its ``psum`` stays restricted to the
``nodes`` axis (``core.gwu``), so §3 and §4 compose without interfering.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.roofline import HW

__all__ = [
    "LayerPlan", "NetworkPlan", "plan_network", "plan_for_axes",
    "network_param_bytes", "plan_scope", "take", "current_plan",
    "grad_combine", "rep_in", "shard_dim", "gather_cols",
]

_F32 = 4                      # bytes per element (the repro trains f32)
_BWD_MULT = 3.0               # fwd + backward ≈ 3x forward FLOPs


# ----------------------------------------------------------------------
# the plan
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's resolved parallelization on the ``model`` mesh axis.

    ``parallel_dim`` is what actually executes: ``batch`` (activations
    sharded over ``model`` on the batch dim), ``channel`` (fc columns
    sharded, Megatron dataflow) or ``replicate`` (full compute on every
    ``model`` device).  ``spec`` is the activation PartitionSpec inside
    one node's step; ``tile`` is the executed kernel grid knob — the
    conv ``oc_tile`` / dense ``block`` chosen by the Alg. 4.2 cost model
    **on the post-sharding local shapes** (0 for pool layers, which take
    no tile).  ``shards``/``axis`` carry the model-axis geometry the
    executing op needs.
    """
    name: str                  # conv0, pool0, fc1, ...
    kind: str                  # "conv" | "pool" | "fc"
    parallel_dim: str          # "batch" | "channel" | "replicate"
    spec: P                    # activation spec inside the node step
    tile: int                  # executed kernel tile (0: no tile knob)
    shards: int = 1            # model-axis size the plan was built for
    axis: str = "model"
    flops: float = 0.0         # per-device FLOPs (fwd+bwd) under the plan
    comm_bytes: float = 0.0    # per-step model-axis collective bytes
    cost_s: float = 0.0        # roofline seconds for this layer


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    """The per-layer plans plus the mesh-facing specs the engine uses.

    ``batch_spec`` is the host-stacked batch placement (leaves are
    ``(nodes, local_steps, B, ...)``); ``param_spec`` the node-stacked
    param/opt placement.  ``combine_grads`` says whether the round must
    recombine per-shard grads with :func:`grad_combine` (the ``batch``
    family) — the ``channel``/``replicate`` families keep gradients
    replicated by construction.  Hashable: the trainer keys its compiled
    round cache on ``(mesh, plan)``.
    """
    nodes: int
    model: int
    family: str                # "batch" | "channel" | "replicate"
    layers: tuple              # tuple[LayerPlan, ...] in forward order
    batch_spec: P
    param_spec: P
    combine_grads: bool
    total_cost_s: float
    axis: str = "model"


def network_param_bytes(cfg) -> int:
    """f32 bytes of one replica of the CNN's weights (Eq. 11 payload)."""
    from repro.models.cnn import _conv_shapes
    shapes, final = _conv_shapes(cfg)
    total = 0
    for cin, cout, _, _ in shapes:
        total += (cfg.filter_size * cfg.filter_size * cin * cout + cout)
    dims = [final * final * cfg.filters] + \
        [cfg.fc_neurons] * (cfg.fc_layers - 1) + [cfg.num_classes]
    for j in range(cfg.fc_layers):
        total += dims[j] * dims[j + 1] + dims[j + 1]
    return total * _F32


# ----------------------------------------------------------------------
# roofline scoring (the cost model candidates are ranked by)
# ----------------------------------------------------------------------
def _roof(flops: float, mem_bytes: float, comm_bytes: float, hw: HW) -> float:
    return max(flops / hw.peak_flops, mem_bytes / hw.hbm_bw) \
        + comm_bytes / hw.ici_bw


def _allreduce_bytes(nbytes: float, k: int) -> float:
    """Ring all-reduce wire bytes per participant for a k-way psum."""
    return 2.0 * (k - 1) / k * nbytes if k > 1 else 0.0


def _gather_bytes(nbytes: float, k: int) -> float:
    """Ring all-gather wire bytes per participant (output size nbytes)."""
    return (k - 1) / k * nbytes if k > 1 else 0.0


def _candidate(dim: str, flops: float, mem: float, comm: float,
               hw: HW) -> dict:
    return {"dim": dim, "flops": flops, "comm": comm,
            "cost": _roof(flops, mem, comm, hw)}


def _conv_candidates(B: int, cin: int, cout: int, size: int, ksz: int,
                     K: int, hw: HW) -> dict:
    """Feasible model-axis parallelizations of one conv layer.

    ``channel`` conv (filter partitioning with psum'd partial sums) is a
    planned-but-not-yet-executed dimension — until the executing op grows
    it, the planner does not offer it, keeping plan == execution honest.
    """
    flops = _BWD_MULT * 2.0 * B * size * size * ksz * ksz * cin * cout
    acts = _F32 * B * size * size * (cin + cout)
    wbytes = _F32 * (ksz * ksz * cin * cout + cout)
    out = {"replicate": _candidate("replicate", flops, acts + wbytes, 0.0,
                                   hw)}
    if K > 1 and B % K == 0:
        out["batch"] = _candidate(
            "batch", flops / K, acts / K + wbytes,
            _allreduce_bytes(wbytes, K), hw)
    return out


def _fc_candidates(B: int, d_in: int, d_out: int, K: int, hw: HW) -> dict:
    flops = _BWD_MULT * 2.0 * B * d_in * d_out
    a_in, a_out = _F32 * B * d_in, _F32 * B * d_out
    wbytes = _F32 * (d_in * d_out + d_out)
    out = {"replicate": _candidate("replicate", flops, a_in + a_out + wbytes,
                                   0.0, hw)}
    if K > 1 and B % K == 0:
        out["batch"] = _candidate(
            "batch", flops / K, (a_in + a_out) / K + wbytes,
            _allreduce_bytes(wbytes, K), hw)
    if K > 1 and d_out % K == 0:
        # fwd all-gather of the column-sharded output + the transposes:
        # dx psum (rep_in) and the zero-padded dw/db psum (shard_dim)
        comm = _gather_bytes(a_out, K) + _allreduce_bytes(a_in, K) \
            + _allreduce_bytes(wbytes, K)
        out["channel"] = _candidate(
            "channel", flops / K, a_in + (a_out + wbytes) / K, comm, hw)
    return out


def _pool_candidates(B: int, cout: int, size: int, K: int, hw: HW) -> dict:
    flops = _BWD_MULT * B * size * size * cout
    acts = _F32 * B * size * size * cout * 1.25
    out = {"replicate": _candidate("replicate", flops, acts, 0.0, hw)}
    if K > 1 and B % K == 0:
        out["batch"] = _candidate("batch", flops / K, acts / K, 0.0, hw)
    return out


_SPEC_OF = {
    # activation PartitionSpec inside one node's step, by parallel dim:
    # batch-sharded rows / column-sharded features / fully replicated
    "batch": P("model"),
    "channel": P(None, "model"),
    "replicate": P(),
}


def _walk_layers(cfg, B: int, K: int, hw: HW):
    """-> list of (name, kind, candidates) in forward order."""
    from repro.models.cnn import _conv_shapes
    shapes, final = _conv_shapes(cfg)
    walk = []
    for i, (cin, cout, size, pooled) in enumerate(shapes):
        walk.append((f"conv{i}", "conv", (cin, cout, size),
                     _conv_candidates(B, cin, cout, size, cfg.filter_size,
                                      K, hw)))
        if pooled:
            walk.append((f"pool{i}", "pool", (cout, size),
                         _pool_candidates(B, cout, size, K, hw)))
    dims = [final * final * cfg.filters] + \
        [cfg.fc_neurons] * (cfg.fc_layers - 1) + [cfg.num_classes]
    for j in range(cfg.fc_layers):
        walk.append((f"fc{j}", "fc", (dims[j], dims[j + 1]),
                     _fc_candidates(B, dims[j], dims[j + 1], K, hw)))
    return walk


def _tile_for(kind: str, dim: str, dims, B: int, K: int,
              workers: int) -> int:
    """The executed kernel tile on the plan's post-sharding local shapes —
    the Alg. 4.2 cost model scores the grid the kernel will actually run."""
    from repro.core.dag import choose_fc_block, choose_oc_tile
    if kind == "conv":
        _, cout, _ = dims
        local_b = B // K if dim == "batch" else B
        return choose_oc_tile(max(local_b, 1), cout, workers=workers)
    if kind == "fc":
        _, d_out = dims
        local_out = d_out // K if dim == "channel" else d_out
        return choose_fc_block(local_out, workers=workers)
    return 0


def plan_for_axes(cfg, *, nodes: int, model: int, batch_size: int = 32,
                  workers: int = 8, family: str = "",
                  hw: Optional[HW] = None) -> NetworkPlan:
    """Plan the network for explicit ``(nodes, model)`` axis sizes.

    The mesh-free core of :func:`plan_network` — also what the hillclimb
    search loop scores candidate axis splits with (no devices needed).
    ``family`` forces ``"batch"`` or ``"channel"`` (tests, search);
    ``""`` picks the cheaper feasible family.  ``cfg=None`` plans the
    generic model-agnostic batch family (no per-layer tiles) — the 2-D
    engine's fallback when the trainer has no ``CNNConfig``.
    """
    hw = hw or HW()
    K = max(int(model), 1)
    if cfg is None:
        if family and family != "batch":
            raise ValueError(
                f"family {family!r} needs a CNNConfig: only the generic "
                "batch plan is model-agnostic")
        if K > 1 and batch_size % K:
            raise ValueError(
                f"generic 2-D plan needs batch_size ({batch_size}) "
                f"divisible by the model axis ({K}); pass the model "
                "config for a per-layer channel/replicate plan")
        return NetworkPlan(
            nodes=nodes, model=K,
            family="batch" if K > 1 else "replicate", layers=(),
            batch_spec=P("nodes", None, "model") if K > 1 else P("nodes"),
            param_spec=P("nodes"), combine_grads=K > 1, total_cost_s=0.0)

    walk = _walk_layers(cfg, batch_size, K, hw)
    forced = bool(family)

    def assemble(fam: str):
        """-> (assignments, total_cost) or None when infeasible."""
        dims = []
        total = 0.0
        for _, kind, _, cands in walk:
            if fam == "batch":
                pick = cands.get("batch")
                if pick is None:
                    return None                  # batch % model mismatch
            elif fam == "channel":
                # per-layer choice (1802.04924): each fc independently
                # column-parallel when divisible AND cheaper; the batch
                # stays replicated so the chain needs no resharding.  A
                # FORCED channel family goes column-parallel wherever
                # divisible — the caller (test/search) demanded the
                # dimension, not the cost ranking.
                pick = cands["replicate"]
                ch = cands.get("channel")
                if kind == "fc" and ch is not None \
                        and (forced or ch["cost"] < pick["cost"]):
                    pick = ch
            else:
                pick = cands["replicate"]
            dims.append(pick)
            total += pick["cost"]
        return dims, total

    if K == 1:
        family = family or "replicate"
    choices = {}
    for fam in ([family] if family else ["batch", "channel"]):
        got = assemble(fam)
        if got is None:
            if family:
                raise ValueError(
                    f"family 'batch' infeasible: batch_size "
                    f"({batch_size}) does not divide over the model "
                    f"axis ({K})")
            continue
        choices[fam] = got
    if not choices:
        raise ValueError("no feasible plan family")
    fam = min(choices, key=lambda f: choices[f][1])
    picks, total = choices[fam]

    layer_plans = []
    for (name, kind, dims, _), pick in zip(walk, picks, strict=True):
        layer_plans.append(LayerPlan(
            name=name, kind=kind, parallel_dim=pick["dim"],
            spec=_SPEC_OF[pick["dim"]],
            tile=_tile_for(kind, pick["dim"], dims, batch_size, K, workers),
            shards=K, flops=pick["flops"], comm_bytes=pick["comm"],
            cost_s=pick["cost"]))

    sharded_batch = fam == "batch" and K > 1
    return NetworkPlan(
        nodes=nodes, model=K, family=fam, layers=tuple(layer_plans),
        batch_spec=P("nodes", None, "model") if sharded_batch
        else P("nodes"),
        param_spec=P("nodes"), combine_grads=sharded_batch,
        total_cost_s=total)


def plan_network(cfg, mesh, batch_size: int = 32, workers: int = 8,
                 family: str = "") -> NetworkPlan:
    """Per-layer parallelization plan for a concrete mesh.

    ``cfg`` is the ``CNNConfig`` (or None for the generic batch plan);
    ``mesh`` any mesh with a ``nodes`` axis — a ``model`` axis switches
    the inner layer on, its absence degrades to the 1-D outer layer.
    The returned specs and tiles are exactly what ``ShardMapEngine``
    executes (asserted by the planner tests).
    """
    shape = dict(mesh.shape)
    return plan_for_axes(cfg, nodes=shape.get("nodes", 1),
                         model=shape.get("model", 1),
                         batch_size=batch_size, workers=workers,
                         family=family)


# ----------------------------------------------------------------------
# plan scope: how the executing ops consume the plan at trace time
# ----------------------------------------------------------------------
class _PlanScope:
    """Trace-time cursor over a plan's layers, per kind.

    ``cnn_forward`` calls its conv/fc ops in a fixed order; each
    ``take`` hands the next same-kind LayerPlan to the executing op and
    records it in ``executed`` — the log the "scheduled == executed"
    tests compare against the plan.  Counters wrap per kind, so every
    full forward traversal (loss fwd, a separate eval trace) realigns.
    """

    def __init__(self, plan: NetworkPlan):
        self.plan = plan
        self._by_kind: dict = {}
        for lp in plan.layers:
            self._by_kind.setdefault(lp.kind, []).append(lp)
        self._cursor = {k: 0 for k in self._by_kind}
        self.executed: list = []

    def take(self, kind: str) -> Optional[LayerPlan]:
        seq = self._by_kind.get(kind)
        if not seq:
            return None
        i = self._cursor[kind]
        self._cursor[kind] = (i + 1) % len(seq)
        lp = seq[i]
        self.executed.append(lp)
        return lp


_SCOPES: list = []


@contextlib.contextmanager
def plan_scope(plan: NetworkPlan):
    """Install ``plan`` for ops traced in this block (re-entrant)."""
    sc = _PlanScope(plan)
    _SCOPES.append(sc)
    try:
        yield sc
    finally:
        _SCOPES.pop()


def take(kind: str) -> Optional[LayerPlan]:
    """The executing op's hook: the next ``kind`` LayerPlan, or None
    when no plan scope is active (every non-planned path)."""
    return _SCOPES[-1].take(kind) if _SCOPES else None


def current_plan() -> Optional[NetworkPlan]:
    return _SCOPES[-1].plan if _SCOPES else None


# ----------------------------------------------------------------------
# batch family: exact per-shard loss/grad recombination over `model`
# ----------------------------------------------------------------------
def grad_combine(plan: NetworkPlan):
    """The model-axis recombiner for batch-family rounds.

    Each shard computes its loss/grads on ``B/K`` samples; weighting by
    the shard's (mask-aware) sample count and ``psum``-ing over ``model``
    reproduces the full-batch mean gradient EXACTLY — for the plain mean
    and for the masked mean of uneven stripes (grad of ``Σlm/Σm``
    decomposes as ``psum(M_s·g_s)/psum(M_s)``).  Runs inside the round's
    ``shard_map`` body, before gradient clipping, so clipping sees the
    same global norm the 1-D paths clip.
    """
    axis = plan.axis

    def combine(loss, grads, batch):
        mask = batch.get("mask") if isinstance(batch, dict) else None
        if mask is not None:
            w = jnp.sum(mask.astype(jnp.float32))
        else:
            leaf = jax.tree_util.tree_leaves(batch)[0]
            w = jnp.asarray(float(leaf.shape[0]), jnp.float32)
        share = w / jnp.maximum(jax.lax.psum(w, axis), 1.0)
        loss = jax.lax.psum(loss * share, axis)
        grads = jax.tree_util.tree_map(
            lambda g: jax.lax.psum(g * share.astype(g.dtype), axis), grads)
        return loss, grads

    return combine


# ----------------------------------------------------------------------
# channel family: replication-aware collectives (Megatron dataflow)
# ----------------------------------------------------------------------
# Plain autodiff through shard_map collectives double-counts replicated
# values: all_gather's transpose psum-scatters K identical cotangents
# (a K× factor), and a sliced weight's transpose leaves each device a
# zero-padded partial dw (model-divergent updates).  These three
# custom-VJP helpers encode the replication the checker can't see —
# together they make the column-parallel fc gradient bit-exact against
# the unsharded layer.

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def rep_in(x, axis_name: str):
    """Identity on a model-replicated input; the backward ``psum``s the
    per-shard partial cotangents into the full (replicated) one."""
    return x


def _rep_in_fwd(x, axis_name):
    return x, None


def _rep_in_bwd(axis_name, _, g):
    return (jax.lax.psum(g, axis_name),)


rep_in.defvjp(_rep_in_fwd, _rep_in_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def shard_dim(x, num_shards: int, full: int, axis_name: str):
    """This device's block of ``x``'s last dim (``full`` static for the
    backward's zero-pad).  The backward ``psum``s the disjoint padded
    blocks, so the weight cotangent comes back full AND replicated."""
    idx = jax.lax.axis_index(axis_name)
    blk = full // num_shards
    return jax.lax.dynamic_slice_in_dim(x, idx * blk, blk, axis=-1)


def _shard_dim_fwd(x, num_shards, full, axis_name):
    return shard_dim(x, num_shards, full, axis_name), None


def _shard_dim_bwd(num_shards, full, axis_name, _, g):
    idx = jax.lax.axis_index(axis_name)
    blk = full // num_shards
    pad = jnp.zeros(g.shape[:-1] + (full,), g.dtype)
    pad = jax.lax.dynamic_update_slice_in_dim(pad, g, idx * blk, axis=-1)
    return (jax.lax.psum(pad, axis_name),)


shard_dim.defvjp(_shard_dim_fwd, _shard_dim_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def gather_cols(y, num_shards: int, axis_name: str):
    """All-gather column shards into the full (replicated) activation;
    the backward takes the local slice of the replicated cotangent
    instead of psum-scattering K identical copies (the K× trap)."""
    return jax.lax.all_gather(y, axis_name, axis=y.ndim - 1, tiled=True)


def _gather_cols_fwd(y, num_shards, axis_name):
    return gather_cols(y, num_shards, axis_name), None


def _gather_cols_bwd(num_shards, axis_name, _, g):
    idx = jax.lax.axis_index(axis_name)
    blk = g.shape[-1] // num_shards
    return (jax.lax.dynamic_slice_in_dim(g, idx * blk, blk, axis=-1),)


gather_cols.defvjp(_gather_cols_fwd, _gather_cols_bwd)
