"""Event-driven heterogeneous-cluster simulator for BPT-CNN's outer layer.

Reproduces the paper's distributed experiments (Figs. 12-15) on a single
host: each virtual computing node has a per-sample processing time; a
virtual clock advances in completion-time order.  The *weight math is real*
(an optional ``worker_train`` callback runs actual JAX training on the
node's IDPA-assigned subset); only wall-clock time is virtual.

Metrics produced:
  * total virtual makespan
  * synchronization waiting time  (Eq. 8, SGWU)
  * communication bytes           (Eq. 11 accounting via ParameterServer)
  * workload balance degree       (Fig. 15b)
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional, Sequence

import numpy as np

from .idpa import IDPAPartitioner, UDPAPartitioner, workload_balance_degree
from .param_server import ParameterServer

__all__ = ["ClusterSim", "SimResult", "make_heterogeneous_speeds"]


def make_heterogeneous_speeds(m: int, spread: float = 0.5,
                              seed: int = 0) -> np.ndarray:
    """Per-sample times for m nodes, uniform in [1-spread/2, 1+spread/2]."""
    rng = np.random.default_rng(seed)
    return 1.0 + spread * (rng.random(m) - 0.5)


# worker_train(worker_id, weights, sample_indices, iteration)
#   -> (new_weights, accuracy)
WorkerTrainFn = Callable[[int, object, np.ndarray, int], tuple]


@dataclasses.dataclass
class SimResult:
    strategy: str
    partitioning: str
    num_nodes: int
    iterations: int
    makespan: float                 # total virtual time
    sync_wait: float                # Eq. (8) (0 for AGWU by construction)
    comm_bytes: int                 # measured, == Eq. (11) for both
    expected_comm_bytes: int        # Eq. (11) closed form
    balance_degree: float           # Fig. 15(b) metric (min/max node busy time)
    allocation: np.ndarray          # samples per node
    final_weights: object = None
    accuracy_trace: list = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        return {
            "strategy": self.strategy,
            "partitioning": self.partitioning,
            "m": self.num_nodes,
            "K": self.iterations,
            "makespan": round(self.makespan, 4),
            "sync_wait": round(self.sync_wait, 4),
            "comm_MB": round(self.comm_bytes / 2**20, 4),
            "balance": round(self.balance_degree, 4),
        }


class ClusterSim:
    """Simulate BPT-CNN outer-layer training on m heterogeneous nodes.

    Parameters
    ----------
    per_sample_time : virtual seconds one node needs per training sample
        (heterogeneity profile; the paper's 1/mu_j up to measurement noise).
    strategy : 'sgwu' | 'agwu'
    partitioning : 'idpa' | 'udpa'
    """

    def __init__(self,
                 num_samples: int,
                 per_sample_time: Sequence[float],
                 iterations: int,
                 batches: int,
                 strategy: str = "agwu",
                 partitioning: str = "idpa",
                 noise: float = 0.0,
                 seed: int = 0,
                 idpa_mode: str = "paper"):
        self.N = int(num_samples)
        self.t = np.asarray(per_sample_time, dtype=np.float64)
        self.m = len(self.t)
        self.K = int(iterations)
        self.A = int(batches)
        if strategy not in ("sgwu", "agwu"):
            raise ValueError(strategy)
        if partitioning not in ("idpa", "udpa"):
            raise ValueError(partitioning)
        self.strategy = strategy
        self.partitioning = partitioning
        self.noise = noise
        self.rng = np.random.default_rng(seed)

        if partitioning == "idpa":
            # nominal frequency = inverse per-sample time (the paper's mu_j)
            self.part = IDPAPartitioner(self.N, self.m, self.A,
                                        frequencies=1.0 / self.t,
                                        mode=idpa_mode)
        else:
            self.part = UDPAPartitioner(self.N, self.m, self.A)

    # ------------------------------------------------------------------
    def _duration(self, node: int, nsamples: int) -> float:
        base = self.t[node] * nsamples
        if self.noise:
            base *= 1.0 + self.noise * (self.rng.random() - 0.5)
        return max(base, 1e-9)

    def _allocate(self, durations: Optional[np.ndarray]) -> np.ndarray:
        """Advance the partitioner one batch; returns cumulative totals."""
        if self.part.current_batch == 0:
            self.part.first_batch()
        elif not self.part.done:
            if isinstance(self.part, IDPAPartitioner):
                self.part.next_batch(durations)
            else:
                self.part.next_batch(None)
        return self.part.totals.copy()

    # ------------------------------------------------------------------
    def run(self,
            init_weights=None,
            worker_train: Optional[WorkerTrainFn] = None,
            eval_fn: Optional[Callable] = None) -> SimResult:
        if self.strategy == "sgwu":
            return self._run_sgwu(init_weights, worker_train, eval_fn)
        return self._run_agwu(init_weights, worker_train, eval_fn)

    # ---------------------------- SGWU --------------------------------
    def _run_sgwu(self, init_weights, worker_train, eval_fn) -> SimResult:
        server = ParameterServer(init_weights if init_weights is not None
                                 else {"w": np.zeros(1, np.float32)}, self.m)
        clock = 0.0
        sync_wait = 0.0
        busy = np.zeros(self.m)
        totals = None
        durations = None
        acc_trace = []

        for it in range(self.K):
            totals = self._allocate(durations) if not self.part.done or \
                totals is None else totals
            durations = np.array(
                [self._duration(j, int(totals[j])) for j in range(self.m)])
            busy += durations
            t_max = float(durations.max())
            sync_wait += float((t_max - durations).sum())   # Eq. (8) term
            clock += t_max

            subs = []
            for j in range(self.m):
                w, _ = server.pull(j)
                if worker_train is not None:
                    idx = self._indices(j, totals)
                    new_w, q = worker_train(j, w, idx, it)
                else:
                    new_w, q = w, 1.0
                subs.append((j, new_w, q))
            server.push_sgwu(subs, virtual_time=clock)
            if eval_fn is not None:
                acc_trace.append((clock, eval_fn(server.global_weights)))

        return self._result(server, clock, sync_wait, busy, totals, acc_trace)

    # ---------------------------- AGWU --------------------------------
    def _run_agwu(self, init_weights, worker_train, eval_fn) -> SimResult:
        server = ParameterServer(init_weights if init_weights is not None
                                 else {"w": np.zeros(1, np.float32)}, self.m)
        busy = np.zeros(self.m)
        iters_done = np.zeros(self.m, dtype=np.int64)
        acc_trace = []

        totals = self._allocate(None)
        # priority queue of (completion_time, node)
        heap: list[tuple[float, int]] = []
        clock = 0.0
        local_w = {}
        # the durations the simulation actually charged each node (most
        # recent work unit) — the IDPA feedback signal, Alg. 3.1's
        # measured t_j.  Re-rolling fresh noisy durations here would
        # consume extra RNG and decouple allocation from observed load.
        charged = np.zeros(self.m)
        for j in range(self.m):
            w, _ = server.pull(j)
            local_w[j] = w
            d = self._duration(j, int(totals[j]))
            charged[j] = d
            busy[j] += d
            heapq.heappush(heap, (d, j))

        while heap:
            t_done, j = heapq.heappop(heap)
            clock = t_done
            it = int(iters_done[j])
            if worker_train is not None:
                idx = self._indices(j, totals)
                new_w, q = worker_train(j, local_w[j], idx, it)
            else:
                new_w, q = local_w[j], 1.0
            server.push_agwu(j, new_w, q, virtual_time=clock)
            if eval_fn is not None:
                acc_trace.append((clock, eval_fn(server.global_weights)))
            iters_done[j] += 1

            # incremental allocation: advance once every node finished
            # iteration `a` (the paper allocates per global batch round),
            # feeding IDPA the durations the simulation charged
            if not self.part.done and int(iters_done.min()) >= \
                    self.part.current_batch:
                totals = self._allocate(charged.copy())

            if iters_done[j] < self.K:
                w, _ = server.pull(j)
                local_w[j] = w
                d = self._duration(j, int(totals[j]))
                charged[j] = d
                busy[j] += d
                heapq.heappush(heap, (t_done + d, j))

        return self._result(server, clock, 0.0, busy, totals, acc_trace)

    # ------------------------------------------------------------------
    def _indices(self, j: int, totals: np.ndarray) -> np.ndarray:
        """Stable per-node sample ranges: node j owns a contiguous stripe."""
        starts = np.concatenate([[0], np.cumsum(totals)[:-1]])
        return np.arange(starts[j], starts[j] + totals[j]) % max(self.N, 1)

    def _result(self, server, clock, sync_wait, busy, totals,
                acc_trace) -> SimResult:
        return SimResult(
            strategy=self.strategy,
            partitioning=self.partitioning,
            num_nodes=self.m,
            iterations=self.K,
            makespan=float(clock),
            sync_wait=float(sync_wait),
            comm_bytes=int(server.comm_bytes),
            expected_comm_bytes=server.expected_comm_bytes(self.K),
            balance_degree=workload_balance_degree(busy),
            allocation=totals,
            final_weights=server.global_weights,
            accuracy_trace=acc_trace,
        )
