"""Event-driven heterogeneous-cluster simulator for BPT-CNN's outer layer.

Reproduces the paper's distributed experiments (Figs. 12-15) on a single
host: each virtual computing node has a per-sample processing time; a
virtual clock advances in completion-time order.  The *weight math is real*
(an optional ``worker_train`` callback runs actual JAX training on the
node's IDPA-assigned subset); only wall-clock time is virtual.

Metrics produced:
  * total virtual makespan
  * synchronization waiting time  (Eq. 8, SGWU)
  * communication bytes           (Eq. 11 accounting via ParameterServer)
  * workload balance degree       (Fig. 15b)
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Optional, Sequence

import numpy as np

from .idpa import IDPAPartitioner, UDPAPartitioner, workload_balance_degree
from .param_server import ParameterServer

__all__ = ["ClusterSim", "SimResult", "make_heterogeneous_speeds"]


def make_heterogeneous_speeds(m: int, spread: float = 0.5,
                              seed: int = 0) -> np.ndarray:
    """Per-sample times for m nodes, uniform in [1-spread/2, 1+spread/2]."""
    rng = np.random.default_rng(seed)
    return 1.0 + spread * (rng.random(m) - 0.5)


# worker_train(worker_id, weights, sample_indices, iteration)
#   -> (new_weights, accuracy)
WorkerTrainFn = Callable[[int, object, np.ndarray, int], tuple]


@dataclasses.dataclass
class SimResult:
    strategy: str
    partitioning: str
    num_nodes: int
    iterations: int
    makespan: float                 # total virtual time
    sync_wait: float                # Eq. (8) (0 for AGWU by construction)
    comm_bytes: int                 # measured, == Eq. (11) for both
    expected_comm_bytes: int        # Eq. (11) closed form
    balance_degree: float           # Fig. 15(b) metric (min/max node busy time)
    allocation: np.ndarray          # samples per node
    final_weights: object = None
    accuracy_trace: list = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        return {
            "strategy": self.strategy,
            "partitioning": self.partitioning,
            "m": self.num_nodes,
            "K": self.iterations,
            "makespan": round(self.makespan, 4),
            "sync_wait": round(self.sync_wait, 4),
            "comm_MB": round(self.comm_bytes / 2**20, 4),
            "balance": round(self.balance_degree, 4),
        }


class ClusterSim:
    """Simulate BPT-CNN outer-layer training on m heterogeneous nodes.

    Parameters
    ----------
    per_sample_time : virtual seconds one node needs per training sample
        (heterogeneity profile; the paper's 1/mu_j up to measurement noise).
    strategy : 'sgwu' | 'agwu'
    partitioning : 'idpa' | 'udpa'
    duration_source : 'model' rolls virtual durations from the per-sample
        heterogeneity profile (+ optional noise) — the explicit simulation
        mode; 'measured' feeds IDPA the *measured* wall time of each
        ``worker_train`` call (requires one), the production feedback path.
    fault_schedule : optional ``core.faults.FaultSchedule`` — node churn.
        SGWU applies transitions at the start of the named iteration; AGWU
        before processing the named push (see the faults module docstring).
    """

    def __init__(self,
                 num_samples: int,
                 per_sample_time: Sequence[float],
                 iterations: int,
                 batches: int,
                 strategy: str = "agwu",
                 partitioning: str = "idpa",
                 noise: float = 0.0,
                 seed: int = 0,
                 idpa_mode: str = "paper",
                 duration_source: str = "model",
                 fault_schedule=None):
        self.N = int(num_samples)
        self.t = np.asarray(per_sample_time, dtype=np.float64)
        self.m = len(self.t)
        self.K = int(iterations)
        self.A = int(batches)
        if strategy not in ("sgwu", "agwu"):
            raise ValueError(strategy)
        if partitioning not in ("idpa", "udpa"):
            raise ValueError(partitioning)
        if duration_source not in ("model", "measured"):
            raise ValueError(
                f"duration_source={duration_source!r}: 'model' or 'measured'")
        self.strategy = strategy
        self.partitioning = partitioning
        self.duration_source = duration_source
        self.noise = noise
        self.rng = np.random.default_rng(seed)
        self.faults = fault_schedule if fault_schedule is not None \
            and not fault_schedule.empty else None
        if self.faults is not None:
            self.faults.validate_nodes(self.m)

        if partitioning == "idpa":
            # nominal frequency = inverse per-sample time (the paper's mu_j)
            self.part = IDPAPartitioner(self.N, self.m, self.A,
                                        frequencies=1.0 / self.t,
                                        mode=idpa_mode)
        else:
            self.part = UDPAPartitioner(self.N, self.m, self.A)

    # ------------------------------------------------------------------
    def _duration(self, node: int, nsamples: int) -> float:
        base = self.t[node] * nsamples
        if self.noise:
            base *= 1.0 + self.noise * (self.rng.random() - 0.5)
        return max(base, 1e-9)

    def _allocate(self, durations: Optional[np.ndarray],
                  active: Optional[np.ndarray] = None) -> np.ndarray:
        """Advance the partitioner one batch; returns cumulative totals."""
        if self.part.current_batch == 0:
            self.part.first_batch(active=active)
        elif not self.part.done:
            if isinstance(self.part, IDPAPartitioner):
                self.part.next_batch(durations, active=active)
            else:
                self.part.next_batch(None, active=active)
        return self.part.totals.copy()

    # ------------------------------------------------------------------
    def run(self,
            init_weights=None,
            worker_train: Optional[WorkerTrainFn] = None,
            eval_fn: Optional[Callable] = None) -> SimResult:
        if self.duration_source == "measured" and worker_train is None:
            raise ValueError(
                "duration_source='measured' needs a worker_train callback "
                "to measure — use 'model' for callback-free simulation")
        if self.strategy == "sgwu":
            return self._run_sgwu(init_weights, worker_train, eval_fn)
        return self._run_agwu(init_weights, worker_train, eval_fn)

    # ---------------------------- SGWU --------------------------------
    def _run_sgwu(self, init_weights, worker_train, eval_fn) -> SimResult:
        server = ParameterServer(init_weights if init_weights is not None
                                 else {"w": np.zeros(1, np.float32)}, self.m)
        clock = 0.0
        sync_wait = 0.0
        busy = np.zeros(self.m)
        totals = None
        durations = None
        acc_trace = []

        for it in range(self.K):
            status = self.faults.status_at(it, self.m) if self.faults \
                else None
            alive = status > 0.0 if status is not None \
                else np.ones(self.m, dtype=bool)
            if not alive.any():
                raise RuntimeError(
                    f"fault schedule leaves no node alive at iteration {it}")
            if not self.part.done or totals is None:
                # a just-rejoined node has no measurement from the previous
                # iteration (its duration slot is 0) — it sits this batch
                # out and earns work once it reports a real duration
                active = None
                if self.faults:
                    active = alive.copy()
                    if durations is not None:
                        active &= durations > 0.0
                totals = self._allocate(durations, active=active)

            durations = np.zeros(self.m)
            subs = []
            for j in range(self.m):
                if not alive[j]:
                    # dead: no pull, no compute, missed the barrier —
                    # Eq. 7 excludes it (weight 0, no transfer charged)
                    subs.append((j, None, 0.0))
                    continue
                d = self._duration(j, int(totals[j])) \
                    if self.duration_source == "model" else 0.0
                w, _ = server.pull(j)
                if worker_train is not None:
                    idx = self._indices(j, totals)
                    t0 = time.perf_counter()
                    new_w, q = worker_train(j, w, idx, it)
                    if self.duration_source == "measured":
                        d = max(time.perf_counter() - t0, 1e-9)
                else:
                    new_w, q = w, 1.0
                if status is not None:
                    d *= status[j]          # slow-node factor
                durations[j] = d
                subs.append((j, new_w, q))
            busy += durations
            t_max = float(durations[alive].max())
            sync_wait += float((t_max - durations[alive]).sum())  # Eq. (8)
            clock += t_max
            server.push_sgwu(subs, virtual_time=clock)
            if eval_fn is not None:
                acc_trace.append((clock, eval_fn(server.global_weights)))

        return self._result(server, clock, sync_wait, busy, totals, acc_trace)

    # ---------------------------- AGWU --------------------------------
    def _run_agwu(self, init_weights, worker_train, eval_fn) -> SimResult:
        server = ParameterServer(init_weights if init_weights is not None
                                 else {"w": np.zeros(1, np.float32)}, self.m)
        busy = np.zeros(self.m)
        iters_done = np.zeros(self.m, dtype=np.int64)
        acc_trace = []
        measured = self.duration_source == "measured"

        # churn bookkeeping: a fail bumps the node's epoch, staling its
        # in-flight heap entry (the push is dropped at pop time — lost)
        down: set[int] = set()
        slow = np.ones(self.m)
        epoch = np.zeros(self.m, dtype=np.int64)
        fault_events = self.faults.events if self.faults else ()
        cursor = 0

        totals = self._allocate(None)
        # priority queue of (completion_time, node, epoch-at-schedule)
        heap: list[tuple[float, int, int]] = []
        clock = 0.0
        local_w = {}
        # per-node pending (weights, accuracy): in measured mode the work
        # RUNS at schedule time (its wall time IS the charged duration)
        # and lands on the server when its completion event pops
        pending: dict[int, tuple] = {}
        # the durations the simulation actually charged each node (most
        # recent work unit) — the IDPA feedback signal, Alg. 3.1's
        # measured t_j.  Re-rolling fresh noisy durations here would
        # consume extra RNG and decouple allocation from observed load.
        charged = np.zeros(self.m)

        def schedule(j: int, at: float):
            w, _ = server.pull(j)
            it = int(iters_done[j])
            if measured:
                idx = self._indices(j, totals)
                t0 = time.perf_counter()
                pending[j] = worker_train(j, w, idx, it)
                d = max(time.perf_counter() - t0, 1e-9)
            else:
                local_w[j] = w
                d = self._duration(j, int(totals[j]))
            d *= float(slow[j])
            charged[j] = d
            busy[j] += d
            heapq.heappush(heap, (at + d, j, int(epoch[j])))

        for j in range(self.m):
            schedule(j, 0.0)

        i = 0                                    # successful-push index
        while heap:
            # fault transitions keyed on the push index, applied before
            # the pop — "fail at 5" drops everything in flight from the
            # 5th merge event onward
            while cursor < len(fault_events) and \
                    fault_events[cursor].round <= i:
                e = fault_events[cursor]
                cursor += 1
                if e.kind == "fail":
                    down.add(e.node)
                    epoch[e.node] += 1           # in-flight work is lost
                elif e.kind == "rejoin":
                    down.discard(e.node)
                    if iters_done[e.node] < self.K:
                        schedule(e.node, clock)
                else:
                    slow[e.node] = e.factor
            if not heap:
                break
            t_done, j, ep = heapq.heappop(heap)
            if j in down or ep != int(epoch[j]):
                continue                         # lost push: died mid-round
            clock = t_done
            it = int(iters_done[j])
            if measured:
                new_w, q = pending.pop(j)
            elif worker_train is not None:
                idx = self._indices(j, totals)
                new_w, q = worker_train(j, local_w[j], idx, it)
            else:
                new_w, q = local_w[j], 1.0
            server.push_agwu(j, new_w, q, virtual_time=clock)
            if eval_fn is not None:
                acc_trace.append((clock, eval_fn(server.global_weights)))
            iters_done[j] += 1
            i += 1

            # incremental allocation: advance once every LIVE node finished
            # iteration `a` (the paper allocates per global batch round),
            # feeding IDPA the durations the simulation charged; dead nodes
            # neither gate the batch nor receive any of it
            alive = np.array([jj not in down for jj in range(self.m)])
            if not self.part.done and alive.any() and \
                    int(iters_done[alive].min()) >= self.part.current_batch:
                totals = self._allocate(charged.copy(),
                                        active=alive if down else None)

            if iters_done[j] < self.K:
                schedule(j, t_done)

        return self._result(server, clock, 0.0, busy, totals, acc_trace)

    # ------------------------------------------------------------------
    def _indices(self, j: int, totals: np.ndarray) -> np.ndarray:
        """Stable per-node sample ranges: node j owns a contiguous stripe."""
        starts = np.concatenate([[0], np.cumsum(totals)[:-1]])
        return np.arange(starts[j], starts[j] + totals[j]) % max(self.N, 1)

    def _result(self, server, clock, sync_wait, busy, totals,
                acc_trace) -> SimResult:
        return SimResult(
            strategy=self.strategy,
            partitioning=self.partitioning,
            num_nodes=self.m,
            iterations=self.K,
            makespan=float(clock),
            sync_wait=float(sync_wait),
            comm_bytes=int(server.comm_bytes),
            expected_comm_bytes=server.expected_comm_bytes(self.K),
            balance_degree=workload_balance_degree(busy),
            allocation=totals,
            final_weights=server.global_weights,
            accuracy_trace=acc_trace,
        )
