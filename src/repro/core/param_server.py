"""Versioned parameter server for BPT-CNN's outer layer.

Holds the global weight set, tracks versions, base snapshots per worker and
which versions are in flight — everything Eq. (9)-(10) needs.  Communication
accounting implements Eq. (11): every round trip is 2 transfers of the
weight-set payload.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .gwu import (agwu_gamma, agwu_update, agwu_update_delta, broadcast_tree,
                  sgwu_merge, sgwu_merge_and_rebroadcast,
                  sgwu_merge_and_rebroadcast_sharded)

__all__ = ["ParameterServer", "Submission"]


def _tree_bytes(tree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(tree)))


@dataclasses.dataclass
class Submission:
    worker: int
    base_version: int
    accuracy: float
    virtual_time: float = 0.0


class ParameterServer:
    """Global weight store with SGWU and AGWU update paths."""

    def __init__(self, init_weights, num_workers: int, mesh=None):
        # ``mesh`` switches on DEVICE-RESIDENT mode: the node-stacked
        # replica tree is placed with NamedSharding over the mesh's
        # `nodes` axis (node j's weights on device j; on a 2-D
        # (nodes, model) hybrid mesh the stack simply stays replicated
        # over `model`), the SGWU merge is an on-device weighted
        # all-reduce restricted to `nodes`, and the merged global weights
        # stay replicated across the mesh — versions and comm-bytes are
        # tracked host-side without ever pulling the payload to host.
        self.mesh = mesh
        if mesh is not None:
            if "nodes" not in mesh.axis_names:
                raise ValueError("device-resident mode needs a `nodes` axis")
            if num_workers % mesh.shape["nodes"] != 0:
                raise ValueError(
                    f"{num_workers} workers do not divide the `nodes` "
                    f"axis ({mesh.shape['nodes']})")
            self._node_sharding = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("nodes"))
        self.global_weights = init_weights
        self.version = 0
        self.num_workers = num_workers
        # snapshots of the global weights each worker last pulled (W^(k))
        self._base: dict[int, Any] = {}
        self._base_version: dict[int, int] = {}
        self.weight_bytes = _tree_bytes(init_weights)
        self.comm_bytes = 0          # Eq. (11) accounting
        self.num_updates = 0
        self.update_log: list[Submission] = []
        # node-stacked replica cache for the fused outer layer: the SGWU
        # merge rebroadcasts into the donated stack, so the next round's
        # pull is free.  Ownership moves to the caller on pull (the fused
        # round donates the buffers), hence the hand-off-and-clear below.
        self._stacked: Any = None
        self._stacked_version = -1

    # ------------------------------------------------------------------
    def pull(self, worker: int):
        """Worker fetches the latest global weights (1 transfer)."""
        self._stacked = None    # mixed-API use: don't pin m replica copies
        self._base[worker] = self.global_weights
        self._base_version[worker] = self.version
        self.comm_bytes += self.weight_bytes
        return self.global_weights, self.version

    def pull_all_stacked(self, active=None):
        """All m workers pull at once: one node-stacked replica tree.

        Bookkeeping is identical to m individual ``pull`` calls (m
        transfers, every worker's base version advanced to the current
        version); the payload is a single pytree whose leaves carry a
        leading node axis — the representation the fused outer layer
        trains on.  Ownership of the stack transfers to the caller (the
        fused round donates its buffers); a fresh pull re-broadcasts from
        the global weights only when no cached stack is available.

        ``active`` (per-worker bools) marks failed nodes: they do not
        pull, so they are not charged a transfer and their base version
        stays where it was — Eq. 11 counts only traffic that happened.
        """
        if self._stacked is not None and self._stacked_version == self.version:
            stacked, self._stacked = self._stacked, None
        else:
            self._stacked = None
            stacked = broadcast_tree(self.global_weights, self.num_workers)
            if self.mesh is not None:     # place node j's replica on device j
                stacked = jax.device_put(stacked, self._node_sharding)
        pulls = 0
        for j in range(self.num_workers):
            if active is not None and not active[j]:
                continue
            self._base[j] = self.global_weights
            self._base_version[j] = self.version
            pulls += 1
        self.comm_bytes += pulls * self.weight_bytes
        return stacked, self.version

    def outstanding_versions(self, exclude: Optional[int] = None):
        return [v for w, v in self._base_version.items() if w != exclude]

    # ------------------------------------------------------------------
    def warmup_agwu(self):
        """Pre-jit the AGWU push path (donated Eq. 10 apply) so the first
        real push inside the event loop does not pay compile time."""
        zeros = jax.tree_util.tree_map(jnp.zeros_like, self.global_weights)
        agwu_update(self.global_weights, zeros, self.global_weights,
                    1.0, 1.0, donate_local=True)

    def push_agwu(self, worker: int, local_weights, accuracy: float,
                  virtual_time: float = 0.0, donate: bool = False):
        """AGWU: apply Eq. (10) immediately (1 transfer in).

        With ``donate=True`` the push SUBMITS the local weights: their
        buffers are handed over to the new global weight set (the
        BPTTrainer hot path opts in — the worker re-pulls before its next
        round, so the m× copy the sequential emulation used to pay is
        gone).  The default keeps the caller's tree readable after the
        push.  Donation is skipped automatically for numpy trees and for
        buffers aliasing the current global/base weights.
        """
        if worker not in self._base:
            raise RuntimeError(f"worker {worker} never pulled weights")
        base_w = self._base[worker]
        k = self._base_version[worker]
        gamma = agwu_gamma(k, max(self.version, 1),
                           self.outstanding_versions(exclude=worker))
        self._stacked = None    # any AGWU push stales the replica cache
        self.global_weights = agwu_update(
            self.global_weights, local_weights, base_w, gamma, accuracy,
            donate_local=donate)
        self.version += 1
        self.num_updates += 1
        self.comm_bytes += self.weight_bytes
        self.update_log.append(Submission(worker, k, accuracy, virtual_time))
        return gamma

    def push_agwu_delta(self, worker: int, delta, accuracy: float,
                        virtual_time: float = 0.0):
        """AGWU push of a node-resident delta W_j(k) - W(k) (1 transfer in).

        The device-sharded outer layer computes the delta on the
        submitting node's device; the push ships ONLY the delta payload
        to the server's device and applies Eq. (10) there — the same math
        as ``push_agwu`` split at the subtraction, with identical
        version/comm-bytes bookkeeping (the delta payload is one
        weight-set transfer, exactly like the full-weights push).
        """
        if worker not in self._base:
            raise RuntimeError(f"worker {worker} never pulled weights")
        k = self._base_version[worker]
        gamma = agwu_gamma(k, max(self.version, 1),
                           self.outstanding_versions(exclude=worker))
        leaves = jax.tree_util.tree_leaves(self.global_weights)
        if leaves and isinstance(leaves[0], jax.Array):
            # the physical push: move the delta to the server placement
            delta = jax.device_put(delta, leaves[0].sharding)
        self._stacked = None    # any AGWU push stales the replica cache
        self.global_weights = agwu_update_delta(
            self.global_weights, delta, gamma, accuracy)
        self.version += 1
        self.num_updates += 1
        self.comm_bytes += self.weight_bytes
        self.update_log.append(Submission(worker, k, accuracy, virtual_time))
        return gamma

    def push_sgwu(self, submissions: list[tuple[int, Any, float]],
                  virtual_time: float = 0.0):
        """SGWU: barrier-merge all workers' weights with Eq. (7).

        A submission whose weights are ``None`` marks a node that MISSED
        the barrier (failed mid-round): it enters the merge as the current
        global weights with weight 0 — mathematically excluded — and,
        because its push never arrived, adds no communication volume.
        """
        if len(submissions) != self.num_workers:
            raise RuntimeError("SGWU requires a submission from every worker")
        locals_, accs = [], []
        for worker, w, q in submissions:
            if w is None:                # missed the barrier: no transfer
                locals_.append(self.global_weights)
                accs.append(0.0)
                self.update_log.append(
                    Submission(worker, self.version, 0.0, virtual_time))
                continue
            locals_.append(w)
            accs.append(q)
            self.comm_bytes += self.weight_bytes
            self.update_log.append(
                Submission(worker, self.version, q, virtual_time))
        self._stacked = None    # list-path push stales the replica cache
        self.global_weights = sgwu_merge(locals_, accs)
        self.version += 1
        self.num_updates += 1
        return self.global_weights

    def push_sgwu_stacked(self, stacked_weights,
                          accuracies: Sequence[float],
                          virtual_time: float = 0.0, active=None):
        """SGWU barrier merge against the node-stacked representation.

        ``stacked_weights`` is ONE pytree with a leading node axis of size
        m (worker j's weights at index j); its buffers are DONATED to the
        merged global weights — callers must not reuse the stack after the
        push.  Bookkeeping matches m individual submissions.  ``active``
        marks nodes that missed the barrier (failed mid-round): they must
        arrive with accuracy 0 (Eq. 7 excludes them) and are not charged
        a transfer — their push never happened.
        """
        if len(accuracies) != self.num_workers:
            raise RuntimeError("SGWU requires a submission from every worker")
        for worker, q in enumerate(accuracies):
            if active is not None and not active[worker]:
                if float(q) != 0.0:
                    raise ValueError(
                        f"node {worker} missed the barrier but carries "
                        f"merge weight {q!r} — dead nodes must merge at 0")
                self.update_log.append(
                    Submission(worker, self.version, 0.0, virtual_time))
                continue
            self.comm_bytes += self.weight_bytes
            self.update_log.append(
                Submission(worker, self.version, float(q), virtual_time))
        if self.mesh is not None:
            # on-device weighted all-reduce; merged stays mesh-replicated
            self.global_weights, self._stacked = \
                sgwu_merge_and_rebroadcast_sharded(
                    stacked_weights, accuracies, self.mesh)
        else:
            self.global_weights, self._stacked = sgwu_merge_and_rebroadcast(
                stacked_weights, accuracies)
        self.version += 1
        self.num_updates += 1
        self._stacked_version = self.version
        return self.global_weights

    # ------------------------------------------------------------------
    def expected_comm_bytes(self, iterations: int) -> int:
        """Eq. (11): C = 2 c_w * m * K."""
        return 2 * self.weight_bytes * self.num_workers * iterations

    # ------------------------------------------------------------------
    # crash-safe checkpointing: the host-side bookkeeping (version
    # counters, per-worker base versions, the Eq. 9-11 accounting and the
    # full version log) as a JSON-able dict.  The weight payloads
    # themselves (global weights, per-worker base snapshots) travel in the
    # engine snapshot's ARRAY tree — this dict is everything else a
    # resumed server needs so its next gamma/comm computation is
    # bit-identical to the uninterrupted run's.
    def state_dict(self) -> dict:
        return {
            "version": self.version,
            "num_updates": self.num_updates,
            "comm_bytes": self.comm_bytes,
            "base_version": {str(w): v
                             for w, v in self._base_version.items()},
            "update_log": [[s.worker, s.base_version, s.accuracy,
                            s.virtual_time] for s in self.update_log],
        }

    def load_state_dict(self, state: dict) -> None:
        self.version = int(state["version"])
        self.num_updates = int(state["num_updates"])
        self.comm_bytes = int(state["comm_bytes"])
        self._base_version = {int(w): int(v)
                              for w, v in state["base_version"].items()}
        self.update_log = [Submission(int(w), int(bv), float(q), float(vt))
                           for w, bv, q, vt in state["update_log"]]
        self._stacked = None
        self._stacked_version = -1
