"""Versioned parameter server for BPT-CNN's outer layer.

Holds the global weight set, tracks versions, base snapshots per worker and
which versions are in flight — everything Eq. (9)-(10) needs.  Communication
accounting implements Eq. (11): every round trip is 2 transfers of the
weight-set payload.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

from .gwu import agwu_gamma, agwu_update, sgwu_merge

__all__ = ["ParameterServer", "Submission"]


def _tree_bytes(tree) -> int:
    return int(sum(np.prod(x.shape) * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(tree)))


@dataclasses.dataclass
class Submission:
    worker: int
    base_version: int
    accuracy: float
    virtual_time: float = 0.0


class ParameterServer:
    """Global weight store with SGWU and AGWU update paths."""

    def __init__(self, init_weights, num_workers: int):
        self.global_weights = init_weights
        self.version = 0
        self.num_workers = num_workers
        # snapshots of the global weights each worker last pulled (W^(k))
        self._base: dict[int, Any] = {}
        self._base_version: dict[int, int] = {}
        self.weight_bytes = _tree_bytes(init_weights)
        self.comm_bytes = 0          # Eq. (11) accounting
        self.num_updates = 0
        self.update_log: list[Submission] = []

    # ------------------------------------------------------------------
    def pull(self, worker: int):
        """Worker fetches the latest global weights (1 transfer)."""
        self._base[worker] = self.global_weights
        self._base_version[worker] = self.version
        self.comm_bytes += self.weight_bytes
        return self.global_weights, self.version

    def outstanding_versions(self, exclude: Optional[int] = None):
        return [v for w, v in self._base_version.items() if w != exclude]

    # ------------------------------------------------------------------
    def push_agwu(self, worker: int, local_weights, accuracy: float,
                  virtual_time: float = 0.0):
        """AGWU: apply Eq. (10) immediately (1 transfer in)."""
        if worker not in self._base:
            raise RuntimeError(f"worker {worker} never pulled weights")
        base_w = self._base[worker]
        k = self._base_version[worker]
        gamma = agwu_gamma(k, max(self.version, 1),
                           self.outstanding_versions(exclude=worker))
        self.global_weights = agwu_update(
            self.global_weights, local_weights, base_w, gamma, accuracy)
        self.version += 1
        self.num_updates += 1
        self.comm_bytes += self.weight_bytes
        self.update_log.append(Submission(worker, k, accuracy, virtual_time))
        return gamma

    def push_sgwu(self, submissions: list[tuple[int, Any, float]],
                  virtual_time: float = 0.0):
        """SGWU: barrier-merge all workers' weights with Eq. (7)."""
        if len(submissions) != self.num_workers:
            raise RuntimeError("SGWU requires a submission from every worker")
        locals_, accs = [], []
        for worker, w, q in submissions:
            locals_.append(w)
            accs.append(q)
            self.comm_bytes += self.weight_bytes
            self.update_log.append(
                Submission(worker, self.version, q, virtual_time))
        self.global_weights = sgwu_merge(locals_, accs)
        self.version += 1
        self.num_updates += 1
        return self.global_weights

    # ------------------------------------------------------------------
    def expected_comm_bytes(self, iterations: int) -> int:
        """Eq. (11): C = 2 c_w * m * K."""
        return 2 * self.weight_bytes * self.num_workers * iterations
