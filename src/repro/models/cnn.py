"""The paper's CNN (feature extractor + fully-connected classifier, §3.1).

Configurable to the seven network scales of Table 2.  Every layer routes
through the ``kernels.ops`` dispatch: convolutions via
``models.layers.conv2d`` (bias + relu epilogue fused, Eq. 1+2 as one
pallas_call), pooling via ``ops.max_pool2d`` (Eq. 15 forward / Eq. 18
argmax-routed backward) and the classifier stack via ``models.layers.fc``
(Eq. 19-21 per-block G_FC tasks) — so under ``REPRO_KERNEL_IMPL=pallas``
the WHOLE forward+backward runs differentiable Pallas kernels
(custom_vjp), and under ``ref`` the jnp oracles.  The training objective
is the paper's squared error over output neurons (Eq. 16); gradients via
jax.grad implement Eq. 17-23 exactly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers

__all__ = ["CNNConfig", "init_cnn", "cnn_forward", "cnn_loss", "cnn_accuracy",
           "TABLE2_CASES", "make_case"]


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    image_size: int = 32
    in_channels: int = 3
    conv_layers: int = 2            # layers(Conv) in Table 2
    filters: int = 4                # filters(Conv)
    filter_size: int = 3
    fc_layers: int = 3              # layers(FC)
    fc_neurons: int = 500           # neurons(FC)
    num_classes: int = 10
    pool_every: int = 1             # 2x2 max-pool after every k-th conv

    def __post_init__(self):
        if self.pool_every < 1:
            raise ValueError(
                f"pool_every must be >= 1, got {self.pool_every}")


# Table 2 of the paper
_T2 = {
    "case1": (2, 4, 3, 500), "case2": (4, 4, 3, 1000),
    "case3": (6, 8, 5, 1500), "case4": (8, 8, 5, 1500),
    "case5": (8, 10, 7, 2000), "case6": (10, 10, 7, 2000),
    "case7": (10, 12, 7, 2000),
}
TABLE2_CASES = tuple(_T2)


def make_case(case: str, image_size: int = 32, num_classes: int = 10,
              in_channels: int = 3) -> CNNConfig:
    cl, f, fl, n = _T2[case]
    # deep cases can't pool every layer at 32px; pool only while >= 8px
    return CNNConfig(name=case, image_size=image_size,
                     in_channels=in_channels, conv_layers=cl, filters=f,
                     fc_layers=fl, fc_neurons=n, num_classes=num_classes)


def _conv_shapes(cfg: CNNConfig):
    """Per-layer (in_ch, out_ch, spatial, pooled) with same-padding convs.

    A layer pools iff it is a ``pool_every``-th conv layer AND the feature
    map is still >= 8 px (deep Table-2 cases can't pool every layer at
    32 px without vanishing spatially).
    """
    shapes = []
    size, cin = cfg.image_size, cfg.in_channels
    for i in range(cfg.conv_layers):
        pooled = (i + 1) % cfg.pool_every == 0 and size >= 8
        shapes.append((cin, cfg.filters, size, pooled))
        if pooled:
            size //= 2
        cin = cfg.filters
    return shapes, size


def init_cnn(key, cfg: CNNConfig, dtype=jnp.float32):
    shapes, final = _conv_shapes(cfg)
    params = {"conv": [], "fc": []}
    keys = jax.random.split(key, cfg.conv_layers + cfg.fc_layers)
    for i, (cin, cout, _, _) in enumerate(shapes):
        params["conv"].append(layers.init_conv2d(
            keys[i], cfg.filter_size, cfg.filter_size, cin, cout, dtype))
    d_in = final * final * cfg.filters
    dims = [d_in] + [cfg.fc_neurons] * (cfg.fc_layers - 1) + [cfg.num_classes]
    for j in range(cfg.fc_layers):
        k = keys[cfg.conv_layers + j]
        params["fc"].append(layers.init_fc(k, dims[j], dims[j + 1], dtype))
    return params


def cnn_forward(params, images, cfg: CNNConfig):
    """images: (B, H, W, C) -> logits (B, classes)."""
    from repro.kernels import ops
    x = images
    shapes, _ = _conv_shapes(cfg)
    for p, (_, _, _, pooled) in zip(params["conv"], shapes, strict=True):
        x = layers.conv2d(p, x, padding="SAME", activation="relu")
        if pooled:
            x = ops.max_pool2d(x, window=2, stride=2)
    x = x.reshape(x.shape[0], -1)
    for j, p in enumerate(params["fc"]):
        hidden = j < len(params["fc"]) - 1
        x = layers.fc(p, x, activation="relu" if hidden else "none")
    return x


def cnn_loss(params, batch, cfg: CNNConfig):
    """Paper's Eq. 16: squared error over output neurons (one-hot labels).

    An optional ``batch["mask"]`` (B,) of 0/1 weights drops padded rows —
    the uneven per-node stripes of
    ``IDPADataset.stacked_round_batches(uneven=True)`` — by switching the
    batch mean to a masked mean over the real samples.
    """
    logits = cnn_forward(params, batch["images"], cfg)
    y = jax.nn.one_hot(batch["labels"], cfg.num_classes, dtype=logits.dtype)
    probs = jax.nn.softmax(logits, axis=-1)
    per_example = jnp.sum((y - probs) ** 2, axis=-1)
    mask = batch.get("mask")
    if mask is None:
        return jnp.mean(per_example)
    mask = mask.astype(per_example.dtype)
    return jnp.sum(per_example * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def cnn_accuracy(params, batch, cfg: CNNConfig):
    logits = cnn_forward(params, batch["images"], cfg)
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                    .astype(jnp.float32))
