"""Mixture-of-Experts layer with top-k routing and expert parallelism.

Dispatch is sort-based per batch row (no O(T·E·C) one-hot einsum): token
copies are sorted by expert id, scattered into a padded (E, C) capacity
buffer, run through a batched expert matmul (experts shardable over the
`model` mesh axis = expert parallelism), and combined back weighted by the
router probability.  Keeping routing per batch row keeps the sort local
under data-parallel sharding (no global all-gather for the argsort).

The router is the BPT-CNN inner-layer *scheduler* analogue: experts are the
"threads", the top-k router the priority assignment, capacity the
load-balance constraint (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.shardlib import constrain, constrain_div

from .layers import init_dense

__all__ = ["init_moe", "moe_layer", "load_balance_loss"]


def init_moe(key, d_model: int, num_experts: int, expert_d_ff: int,
             dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / jnp.sqrt(d_model)
    s_out = 1.0 / jnp.sqrt(expert_d_ff)
    return {
        "router": init_dense(k1, d_model, num_experts, dtype),
        "wi": jax.random.normal(k2, (num_experts, d_model, expert_d_ff),
                                dtype) * s_in,
        "wg": jax.random.normal(k3, (num_experts, d_model, expert_d_ff),
                                dtype) * s_in,
        "wo": jax.random.normal(k4, (num_experts, expert_d_ff, d_model),
                                dtype) * s_out,
    }


def load_balance_loss(probs, expert_mask):
    """Switch-style aux loss: E * sum_e f_e * p_e.

    probs: (B, S, E) router softmax;  expert_mask: (B, S, E) 0/1 top-k hits.
    """
    E = probs.shape[-1]
    f = jnp.mean(expert_mask, axis=(0, 1))          # fraction routed
    p = jnp.mean(probs, axis=(0, 1))                # mean router prob
    return E * jnp.sum(f * p)


def moe_layer(params, x, cfg, capacity_factor: float = 0.0):
    """x: (B, S, d_model) -> (out, aux_loss)."""
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    logits = x @ params["router"]["w"].astype(x.dtype)       # (B,S,E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # (B,S,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux loss from the full distribution
    expert_mask = jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=2)
    aux = load_balance_loss(probs, expert_mask)

    # ---- per-row sort-based dispatch ----
    T = S * k
    C = max(1, int(S * k * capacity_factor / E))             # per-row capacity
    flat_e = top_e.reshape(B, T)                             # (B,T)
    flat_p = top_p.reshape(B, T)
    tok_idx = jnp.broadcast_to(jnp.arange(S)[:, None], (S, k)).reshape(T)

    order = jnp.argsort(flat_e, axis=-1)                     # stable
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # position within expert = rank - index of expert segment start
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(flat_e)  # (B,E)
    starts = jnp.cumsum(counts, axis=-1) - counts            # (B,E)
    ranks = jnp.arange(T)[None, :] - jnp.take_along_axis(starts, sorted_e,
                                                         axis=-1)
    keep = ranks < C                                          # drop overflow
    # dropped copies go to a trash slot E*C (sliced off below)
    slot = jnp.where(keep, sorted_e * C + ranks, E * C)       # (B,T)
    src_tok = jnp.take_along_axis(
        jnp.broadcast_to(tok_idx[None], (B, T)), order, axis=-1)

    # scatter tokens into (B, E*C [+1 trash], d).  Gather x BEFORE the
    # k-fold copy expansion: otherwise GSPMD all-gathers the (B, S*k, d)
    # copies tensor per appearance — k x the traffic (§Perf hc2 H1).
    x_full = constrain(x, "batch", None, None)
    xv = jnp.take_along_axis(x_full, src_tok[..., None], axis=1)  # (B,T,d)
    buf = jnp.zeros((B, E * C + 1, d), x.dtype)
    buf = jax.vmap(lambda b, s, u: b.at[s].add(u))(buf, slot, xv)
    # expert-parallel layout: the (E*C+1) flat dim hides E from GSPMD, so
    # re-shard explicitly — this is where the dispatch all-to-all lives
    buf = buf[:, :E * C].reshape(B, E, C, d)
    buf = constrain_div(buf, "batch", "expert", "capacity", None)

    # ---- expert computation (E shardable over `model` axis) ----
    act = jax.nn.silu if cfg.activation == "silu" else jax.nn.gelu
    h = act(jnp.einsum("becd,edf->becf", buf, params["wg"].astype(x.dtype))) \
        * jnp.einsum("becd,edf->becf", buf, params["wi"].astype(x.dtype))
    y = jnp.einsum("becf,efd->becd", h, params["wo"].astype(x.dtype))
    y = constrain_div(y, "batch", "expert", "capacity", None)
    y = y.reshape(B, E * C, d)
    # zero trash row so dropped copies gather zeros
    y = jnp.concatenate([y, jnp.zeros((B, 1, d), y.dtype)], axis=1)

    # ---- combine back ----
    gathered = jax.vmap(lambda yb, s: yb[s])(y, slot)        # (B,T,d)
    sorted_p = jnp.take_along_axis(flat_p, order, axis=-1)
    gathered = gathered * jnp.where(keep, sorted_p, 0.0)[..., None].astype(x.dtype)
    out = jnp.zeros((B, S, d), x.dtype)
    out = jax.vmap(lambda o, t, g: o.at[t].add(g))(out, src_tok, gathered)
    return out, aux
