"""Stub modality frontends (assignment carve-out).

``[audio]`` and ``[vlm]`` architectures specify the transformer backbone
only; the mel-spectrogram/conv feature extractor (audio) and ViT/SigLIP
encoder (vision) are stubs that provide *precomputed* frame/patch embeddings
of the right shape.  These helpers build the ShapeDtypeStructs / random
stand-ins the pipelines and dry-run use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["frontend_embed_shape", "random_frontend_embeds"]


def frontend_embed_shape(cfg, batch: int):
    """(B, P, d_model) for P frontend tokens (patches or audio frames)."""
    if not cfg.frontend:
        return None
    return (batch, cfg.num_frontend_tokens, cfg.d_model)


def random_frontend_embeds(key, cfg, batch: int, dtype=jnp.bfloat16):
    shape = frontend_embed_shape(cfg, batch)
    if shape is None:
        return None
    return jax.random.normal(key, shape, dtype) * 0.02
