"""Common neural-net layers (pure-functional JAX, params as pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.shardlib import constrain

__all__ = [
    "rms_norm", "init_dense", "dense", "init_mlp", "mlp",
    "rope_frequencies", "apply_rope", "init_embedding", "embed",
    "softcap", "init_rms_norm", "init_conv2d", "conv2d", "init_fc", "fc",
]


def init_rms_norm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


def softcap(x, cap: float):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return jnp.tanh(x / cap) * cap


def init_conv2d(key, kh: int, kw: int, c_in: int, c_out: int,
                dtype=jnp.float32):
    """He-initialised HWIO conv filter + zero bias."""
    fan = c_in * kh * kw
    return {
        "w": jax.random.normal(key, (kh, kw, c_in, c_out), dtype)
        * jnp.sqrt(2.0 / fan),
        "b": jnp.zeros((c_out,), dtype),
    }


def conv2d(params, x, padding: str = "SAME", stride: int = 1,
           activation: str = "none"):
    """Conv + fused bias/activation via the kernels.ops dispatch.

    All model conv sites go through here so ``REPRO_KERNEL_IMPL=pallas``
    trains through the differentiable Pallas kernel (custom_vjp backward),
    and ``ref`` lowers the jnp oracle — one switch, one call site.
    """
    from repro.kernels import ops
    return ops.conv2d(x, params["w"], params["b"], padding=padding,
                      stride=stride, activation=activation)


def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = 1.0 / jnp.sqrt(d_in)
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}


def dense(params, x):
    """Bias-free projection via the kernels.ops dispatch.

    Every model matmul site goes through here, so
    ``REPRO_KERNEL_IMPL=pallas`` runs the differentiable Pallas dense
    kernel (custom_vjp backward) and ``ref`` lowers ``x @ w`` — one
    switch, one call site, same as ``conv2d``.
    """
    from repro.kernels import ops
    return ops.dense(x, params["w"])


def init_fc(key, d_in: int, d_out: int, dtype=jnp.float32):
    """He-initialised full-connection layer (weight + zero bias, §4.1.2)."""
    return {
        "w": jax.random.normal(key, (d_in, d_out), dtype)
        * jnp.sqrt(2.0 / d_in),
        "b": jnp.zeros((d_out,), dtype),
    }


def fc(params, x, activation: str = "none"):
    """Full-connection layer + fused bias/activation via kernels.ops.

    The CNN's classifier stack (paper §4.1.2, Eq. 19-21) routes through
    here so the pallas impl runs the whole-layer training step — forward
    matmul+epilogue and per-block G_FC gradient tasks — in Pallas.
    Inside a ``core.planner`` plan scope (2-D hybrid-mesh rounds) the
    dispatch also takes the layer's planned tile / channel-parallel
    dataflow from the active ``LayerPlan`` — see ``kernels.ops.dense``.
    """
    from repro.kernels import ops
    return ops.dense(x, params["w"], params["b"], activation=activation)


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": init_dense(k1, d_model, d_ff, dtype),
        "wg": init_dense(k2, d_model, d_ff, dtype),
        "wo": init_dense(k3, d_ff, d_model, dtype),
    }


def mlp(params, x, activation: str = "silu", megatron: bool = False):
    """Gated MLP (SwiGLU / GeGLU).

    megatron=True: classic TP dataflow — all-gather x over seq once, keep
    the hidden ff-sharded on `model`, reduce-scatter the output back to
    seq-sharded (cheaper than GSPMD's default per-matmul weight gathers
    when d_ff >> d_model; §Perf hillclimb 1).
    """
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    if megatron:
        x = constrain(x, "batch", None, None)          # gather seq
        h = act(dense(params["wg"], x)) * dense(params["wi"], x)
        h = constrain(h, "batch", None, "mlp_ff")      # ff stays sharded
        y = dense(params["wo"], h)
        return constrain(y, "batch", "seq", None)      # reduce-scatter
    h = act(dense(params["wg"], x)) * dense(params["wi"], x)
    return dense(params["wo"], h)


# ----------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float = 10_000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    return inv                                         # (head_dim/2,)


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    inv = rope_frequencies(head_dim, theta)
    ang = positions[..., :, None].astype(jnp.float32) * inv   # (..., S, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Embeddings
# ----------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)
