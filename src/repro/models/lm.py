"""Decoder-only language model: init / forward / loss / prefill / decode.

Layer stacks are ``lax.scan`` over params stacked on a leading layer axis
(init via vmap) so the lowered HLO stays compact at 512 devices.  The loss
is a sequence-chunked cross-entropy: logits are never materialised for the
full sequence (vocab up to 256k would otherwise dominate memory).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.shardlib import constrain

from .blocks import (block_decode, block_forward, init_block,
                     init_block_cache, layer_windows)
from .layers import embed, init_embedding, init_rms_norm, rms_norm, softcap

__all__ = ["init_params", "forward", "loss_fn", "init_cache", "decode_step",
           "chunked_cross_entropy", "DecodeCache", "prefill", "cache_insert",
           "cache_evict"]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_params(key, cfg):
    pdt = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    params = {
        "embed": init_embedding(k_embed, cfg.vocab_size, cfg.d_model, pdt),
        "layers": jax.vmap(lambda k: init_block(k, cfg, pdt))(layer_keys),
        "final_norm": init_rms_norm(cfg.d_model, pdt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(k_head, cfg.vocab_size,
                                           cfg.d_model, pdt)
    if cfg.frontend:
        # stub modality projector (ViT/audio-codec outputs -> d_model)
        params["frontend_proj"] = {
            "w": jax.random.normal(k_head, (cfg.d_model, cfg.d_model), pdt)
            * (1.0 / jnp.sqrt(cfg.d_model))}
    return params


def _head_table(params):
    return params.get("lm_head", params["embed"])["table"]


# ----------------------------------------------------------------------
def forward(params, tokens, cfg, frontend_embeds=None, collect_cache=False,
            remat=False, scan_unroll=False, cache_dtype=jnp.bfloat16):
    """tokens: (B, S_text) int32; frontend_embeds: (B, P, d_model) or None.

    Returns (hidden (B,S,d), stacked per-layer decode caches or None,
    aux_loss).  With ``collect_cache`` the middle value is the
    ``init_block_cache``-layout pytree stacked over layers — the whole
    prompt's decode state from ONE forward pass (the prefill path).
    """
    dt = _dtype(cfg)
    if cfg.embed_onehot:
        # one-hot matmul lookup (MaxText-style): contraction over the
        # vocab-sharded dim -> psum(x) instead of a full-table all-gather,
        # and d_table comes out naturally vocab-sharded in the backward
        # (kills the full-size dtable all-reduce; §Perf hc1 H7)
        table = params["embed"]["table"]
        oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=dt)
        oh = constrain(oh, "batch", None, "vocab")
        x = (oh @ table.astype(dt))
    elif cfg.embed_reshard:
        # reshard the vocab-sharded table to d-sharded (one cheap
        # all-to-all of table_bytes/16) so the token gather is local —
        # instead of GSPMD's full-table all-gather (§Perf hc1 H5)
        table = constrain(params["embed"]["table"], None, "tp")
        x = jnp.take(table, tokens, axis=0).astype(dt)
        x = constrain(x, "batch", None, "tp")
    else:
        x = embed(params["embed"], tokens).astype(dt)
    if frontend_embeds is not None:
        fe = frontend_embeds.astype(dt) @ params["frontend_proj"]["w"].astype(dt)
        x = jnp.concatenate([fe, x], axis=1)
    B, S, _ = x.shape
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    windows = layer_windows(cfg)

    def block(lp, x, win):
        # sequence-sharded at the layer boundary (Megatron-SP style): the
        # scan carry — the only full-activation residency — stays 1/|model|
        if cfg.bf16_params_compute:
            # barrier anchors the convert so GSPMD's weight all-gathers
            # move bf16, not the f32 originals (gather/convert otherwise
            # commute and the gather goes first — measured 2x traffic)
            lp = jax.tree_util.tree_map(
                lambda p: jax.lax.optimization_barrier(p.astype(dt))
                if p.ndim >= 2 else p, lp)
        x = constrain(x, "batch", "seq", "embed")
        x, kv, a = block_forward(lp, x, positions, cfg, window=win,
                                 collect_cache=collect_cache,
                                 cache_dtype=cache_dtype)
        return constrain(x, "batch", "seq", "embed"), kv, a

    if remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, layer_in):
        x, aux = carry
        lp, win = layer_in
        x, kv, a = block(lp, x, win)
        ys = kv if collect_cache else None
        return (x, aux + a), ys

    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], windows), unroll=cfg.num_layers if scan_unroll else 1)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, caches, aux


def chunked_cross_entropy(hidden, head_table, labels, cfg, chunk: int = 0):
    """Mean CE over (B,S) without materialising (B,S,V) at once."""
    B, S, D = hidden.shape
    chunk = min(chunk or cfg.ce_chunk or 512, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = jnp.moveaxis(hidden.reshape(B, nc, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    table = head_table.astype(hidden.dtype)

    @jax.checkpoint
    def ce_chunk(carry, inp):
        h, lbl = inp
        logits = constrain(h @ table.T, "batch", None, "vocab")
        if cfg.final_softcap:
            logits = softcap(logits, cfg.final_softcap)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lbl, 0)[..., None], axis=-1)[..., 0]
        valid = (lbl >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(ce_chunk, (jnp.zeros(()), jnp.zeros(())),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg, aux_weight: float = 0.01, remat: bool = False,
            scan_unroll: bool = False):
    """batch: {'tokens': (B,S), 'labels': (B,S), ['frontend_embeds']}."""
    hidden, _, aux = forward(params, batch["tokens"], cfg,
                             frontend_embeds=batch.get("frontend_embeds"),
                             remat=remat, scan_unroll=scan_unroll)
    labels = batch["labels"]
    if "frontend_embeds" in batch and batch["frontend_embeds"] is not None:
        # frontend positions carry no next-token loss
        P = batch["frontend_embeds"].shape[1]
        pad = jnp.full((labels.shape[0], P), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce = chunked_cross_entropy(hidden, _head_table(params), labels, cfg)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ----------------------------------------------------------------------
# Serving
# ----------------------------------------------------------------------
@dataclasses.dataclass
class DecodeCache:
    """Slot-major decode cache.

    ``layers``: per-layer cache pytree stacked over layers — every leaf
    has leading axis L (layers) and axis 1 = slot/batch.  ``lengths``:
    (slots,) int32 valid-token counts; 0 marks a free slot.  Registered
    as a pytree node so it flows through jit / donate / tree_map intact.
    """
    layers: Any
    lengths: jax.Array


jax.tree_util.register_dataclass(
    DecodeCache, data_fields=("layers", "lengths"), meta_fields=())


def init_cache(batch, max_seq: Optional[int] = None, cfg=None,
               dtype=jnp.bfloat16) -> "DecodeCache":
    """Slot-major decode cache for ``batch`` slots of ``max_seq`` tokens.

    Signature is cfg-LAST, matching ``forward``/``loss_fn``/``decode_step``.
    The legacy ``init_cache(cfg, batch, max_seq)`` order is detected and
    shimmed with a DeprecationWarning.
    """
    if hasattr(batch, "arch_type"):     # legacy (cfg, batch, max_seq) order
        warnings.warn(
            "init_cache(cfg, batch, max_seq) is deprecated; pass cfg last: "
            "init_cache(batch, max_seq, cfg)",
            DeprecationWarning, stacklevel=2)
        batch, max_seq, cfg = max_seq, cfg, batch

    def one(_):
        return init_block_cache(batch, max_seq, cfg, dtype)

    layers = jax.vmap(one)(jnp.arange(cfg.num_layers))
    return DecodeCache(layers=layers,
                       lengths=jnp.zeros((batch,), jnp.int32))


def prefill(params, tokens, cfg, cache_dtype=jnp.bfloat16):
    """Whole-prompt prefill as ONE forward pass (no per-token Python loop).

    tokens: (B, P) int32.  Returns (last-position logits (B, 1, V) f32,
    DecodeCache whose kv seq dim is P and whose lengths are all P) —
    the exact state P sequential ``decode_step`` calls would build.
    Insert the returned slice into a serving cache with ``cache_insert``.
    """
    dt = _dtype(cfg)
    hidden, layers, _ = forward(params, tokens, cfg, collect_cache=True,
                                cache_dtype=cache_dtype)
    x = hidden[:, -1:]
    logits = x @ _head_table(params).astype(dt).T
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    B, P = tokens.shape
    return logits.astype(jnp.float32), DecodeCache(
        layers=layers, lengths=jnp.full((B,), P, jnp.int32))


def cache_insert(cache: "DecodeCache", slice_: "DecodeCache", slot,
                 row=0) -> "DecodeCache":
    """Copy row ``row`` of a prefill ``slice_`` into ``slot`` of a serving
    cache.  Seq-dim leaves (kv) may be shorter in the slice — they land at
    positions [0, P); everything past is masked out by ``lengths``.
    """
    slot = jnp.asarray(slot, jnp.int32)
    row = jnp.asarray(row, jnp.int32)

    def upd(big, small):
        part = jax.lax.dynamic_slice_in_dim(small, row, 1, axis=1)
        return jax.lax.dynamic_update_slice(
            big, part.astype(big.dtype),
            (jnp.int32(0), slot) + (jnp.int32(0),) * (big.ndim - 2))

    layers = jax.tree_util.tree_map(upd, cache.layers, slice_.layers)
    lengths = cache.lengths.at[slot].set(
        jax.lax.dynamic_index_in_dim(slice_.lengths, row, keepdims=False))
    return DecodeCache(layers=layers, lengths=lengths)


def cache_evict(cache: "DecodeCache", slot) -> "DecodeCache":
    """Free ``slot``: zero its length so decode masks it out entirely.
    The stale kv/ssm payload is left in place — the next ``cache_insert``
    overwrites it and ``lengths`` gates all reads until then.
    """
    slot = jnp.asarray(slot, jnp.int32)
    return DecodeCache(layers=cache.layers,
                       lengths=cache.lengths.at[slot].set(0))


def decode_step(params, cache, cache_len, tokens, cfg, scan_unroll=False):
    """tokens: (B, 1) int32; cache: DecodeCache (or a bare stacked layers
    pytree, legacy).  cache_len: None → use ``cache.lengths`` (continuous
    batching: every occupied slot decodes at its own position and its
    length auto-increments in the returned cache); else a scalar or (B,)
    count used as-is (legacy semantics: lengths pass through unchanged).

    Returns (logits (B,1,V) f32, new_cache of the same type as ``cache``).
    """
    typed = isinstance(cache, DecodeCache)
    layers = cache.layers if typed else cache
    auto = cache_len is None
    if auto:
        if not typed:
            raise ValueError("cache_len=None needs a DecodeCache "
                             "(bare pytree caches carry no lengths)")
        cache_len = cache.lengths
    dt = _dtype(cfg)
    x = embed(params["embed"], tokens).astype(dt)
    windows = layer_windows(cfg)

    def body(x, layer_in):
        lp, lc, win = layer_in
        x, new_c = block_decode(lp, x, lc, cache_len, cfg, window=win)
        return x, new_c

    x, new_layers = jax.lax.scan(body, x, (params["layers"], layers, windows),
                                 unroll=cfg.num_layers if scan_unroll else 1)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ _head_table(params).astype(dt).T
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    logits = logits.astype(jnp.float32)
    if not typed:
        return logits, new_layers
    lengths = cache.lengths
    if auto:
        lengths = jnp.where(lengths > 0, lengths + 1, lengths)
    return logits, DecodeCache(layers=new_layers, lengths=lengths)
