"""Decoder-only language model: init / forward / loss / prefill / decode.

Layer stacks are ``lax.scan`` over params stacked on a leading layer axis
(init via vmap) so the lowered HLO stays compact at 512 devices.  The loss
is a sequence-chunked cross-entropy: logits are never materialised for the
full sequence (vocab up to 256k would otherwise dominate memory).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.shardlib import constrain

from .blocks import (block_decode, block_forward, init_block,
                     init_block_cache, layer_windows)
from .layers import embed, init_embedding, init_rms_norm, rms_norm, softcap

__all__ = ["init_params", "forward", "loss_fn", "init_cache", "decode_step",
           "chunked_cross_entropy"]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_params(key, cfg):
    pdt = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    params = {
        "embed": init_embedding(k_embed, cfg.vocab_size, cfg.d_model, pdt),
        "layers": jax.vmap(lambda k: init_block(k, cfg, pdt))(layer_keys),
        "final_norm": init_rms_norm(cfg.d_model, pdt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(k_head, cfg.vocab_size,
                                           cfg.d_model, pdt)
    if cfg.frontend:
        # stub modality projector (ViT/audio-codec outputs -> d_model)
        params["frontend_proj"] = {
            "w": jax.random.normal(k_head, (cfg.d_model, cfg.d_model), pdt)
            * (1.0 / jnp.sqrt(cfg.d_model))}
    return params


def _head_table(params):
    return params.get("lm_head", params["embed"])["table"]


# ----------------------------------------------------------------------
def forward(params, tokens, cfg, frontend_embeds=None, collect_cache=False,
            remat=False, scan_unroll=False):
    """tokens: (B, S_text) int32; frontend_embeds: (B, P, d_model) or None.

    Returns (hidden (B,S,d), stacked kv cache or None, aux_loss).
    """
    dt = _dtype(cfg)
    if cfg.embed_onehot:
        # one-hot matmul lookup (MaxText-style): contraction over the
        # vocab-sharded dim -> psum(x) instead of a full-table all-gather,
        # and d_table comes out naturally vocab-sharded in the backward
        # (kills the full-size dtable all-reduce; §Perf hc1 H7)
        table = params["embed"]["table"]
        oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=dt)
        oh = constrain(oh, "batch", None, "vocab")
        x = (oh @ table.astype(dt))
    elif cfg.embed_reshard:
        # reshard the vocab-sharded table to d-sharded (one cheap
        # all-to-all of table_bytes/16) so the token gather is local —
        # instead of GSPMD's full-table all-gather (§Perf hc1 H5)
        table = constrain(params["embed"]["table"], None, "tp")
        x = jnp.take(table, tokens, axis=0).astype(dt)
        x = constrain(x, "batch", None, "tp")
    else:
        x = embed(params["embed"], tokens).astype(dt)
    if frontend_embeds is not None:
        fe = frontend_embeds.astype(dt) @ params["frontend_proj"]["w"].astype(dt)
        x = jnp.concatenate([fe, x], axis=1)
    B, S, _ = x.shape
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    windows = layer_windows(cfg)

    def block(lp, x, win):
        # sequence-sharded at the layer boundary (Megatron-SP style): the
        # scan carry — the only full-activation residency — stays 1/|model|
        if cfg.bf16_params_compute:
            # barrier anchors the convert so GSPMD's weight all-gathers
            # move bf16, not the f32 originals (gather/convert otherwise
            # commute and the gather goes first — measured 2x traffic)
            lp = jax.tree_util.tree_map(
                lambda p: jax.lax.optimization_barrier(p.astype(dt))
                if p.ndim >= 2 else p, lp)
        x = constrain(x, "batch", "seq", "embed")
        x, kv, a = block_forward(lp, x, positions, cfg, window=win)
        return constrain(x, "batch", "seq", "embed"), kv, a

    if remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, layer_in):
        x, aux = carry
        lp, win = layer_in
        x, kv, a = block(lp, x, win)
        ys = kv if collect_cache else None
        return (x, aux + a), ys

    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["layers"], windows), unroll=cfg.num_layers if scan_unroll else 1)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, caches, aux


def chunked_cross_entropy(hidden, head_table, labels, cfg, chunk: int = 0):
    """Mean CE over (B,S) without materialising (B,S,V) at once."""
    B, S, D = hidden.shape
    chunk = min(chunk or cfg.ce_chunk or 512, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = jnp.moveaxis(hidden.reshape(B, nc, chunk, D), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    table = head_table.astype(hidden.dtype)

    @jax.checkpoint
    def ce_chunk(carry, inp):
        h, l = inp
        logits = constrain(h @ table.T, "batch", None, "vocab")
        if cfg.final_softcap:
            logits = softcap(logits, cfg.final_softcap)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1)[..., 0]
        valid = (l >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(ce_chunk, (jnp.zeros(()), jnp.zeros(())),
                                 (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg, aux_weight: float = 0.01, remat: bool = False,
            scan_unroll: bool = False):
    """batch: {'tokens': (B,S), 'labels': (B,S), ['frontend_embeds']}."""
    hidden, _, aux = forward(params, batch["tokens"], cfg,
                             frontend_embeds=batch.get("frontend_embeds"),
                             remat=remat, scan_unroll=scan_unroll)
    labels = batch["labels"]
    if "frontend_embeds" in batch and batch["frontend_embeds"] is not None:
        # frontend positions carry no next-token loss
        P = batch["frontend_embeds"].shape[1]
        pad = jnp.full((labels.shape[0], P), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce = chunked_cross_entropy(hidden, _head_table(params), labels, cfg)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# ----------------------------------------------------------------------
# Serving
# ----------------------------------------------------------------------
def init_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Stacked-over-layers decode cache."""
    def one(_):
        return init_block_cache(batch, max_seq, cfg, dtype)
    return jax.vmap(one)(jnp.arange(cfg.num_layers))


def decode_step(params, cache, cache_len, tokens, cfg, scan_unroll=False):
    """tokens: (B, 1) int32; cache_len: scalar int32 count of valid tokens.

    Returns (logits (B,1,V), new_cache).
    """
    dt = _dtype(cfg)
    x = embed(params["embed"], tokens).astype(dt)
    windows = layer_windows(cfg)

    def body(x, layer_in):
        lp, lc, win = layer_in
        x, new_c = block_decode(lp, x, lc, cache_len, cfg, window=win)
        return x, new_c

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache, windows),
                                unroll=cfg.num_layers if scan_unroll else 1)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ _head_table(params).astype(dt).T
    if cfg.final_softcap:
        logits = softcap(logits, cfg.final_softcap)
    return logits.astype(jnp.float32), new_cache
