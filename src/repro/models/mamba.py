"""Mamba-2 mixer via State-Space Duality (SSD), arXiv:2405.21060.

Chunked (block-decomposed) SSD: within-chunk terms are computed as a masked
attention-like matmul (the "dual" quadratic form, MXU-friendly); across-chunk
terms are a linear recurrence over per-chunk states (lax.scan / associative
scan).  Decode is the classic O(1) state update.

This is the TPU-native adaptation of the paper's inner-layer task
decomposition for attention-free architectures: the (chunk × head) grid plays
the role of the conv output-element grid (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense, init_dense

__all__ = ["init_mamba", "mamba_mixer", "mamba_decode_step",
           "init_mamba_cache", "ssd_chunked", "ssd_reference"]


# ----------------------------------------------------------------------
# Parameter init
# ----------------------------------------------------------------------
def init_mamba(key, d_model: int, ssm_heads: int, ssm_head_dim: int,
               ssm_state: int, conv_kernel: int = 4, dtype=jnp.float32):
    """In-projection produces [z (gate), x, B, C, dt]; single group (G=1)."""
    H, P, N = ssm_heads, ssm_head_dim, ssm_state
    d_inner = H * P
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_dim = 2 * d_inner + 2 * N + H
    conv_dim = d_inner + 2 * N
    return {
        "in_proj": init_dense(k1, d_model, proj_dim, dtype),
        "conv_w": jax.random.normal(k2, (conv_kernel, conv_dim), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, float(H), H).astype(dtype)),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": init_dense(k4, d_inner, d_model, dtype),
    }


def _split_proj(zxbcdt, H, P, N):
    d_inner = H * P
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner:2 * d_inner]
    B = zxbcdt[..., 2 * d_inner:2 * d_inner + N]
    C = zxbcdt[..., 2 * d_inner + N:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, x, B, C, dt


# ----------------------------------------------------------------------
# SSD core
# ----------------------------------------------------------------------
def ssd_reference(x, dt, A, B, C, D):
    """Sequential O(L) reference recurrence (oracle for tests).

    x: (b, L, H, P); dt: (b, L, H); A: (H,) < 0; B, C: (b, L, N); D: (H,).
    Returns y: (b, L, H, P).
    """
    b, L, H, P = x.shape
    N = B.shape[-1]

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                       # (b,H,P),(b,H),(b,N),(b,N)
        dA = jnp.exp(dtt * A)                       # (b,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dtt, Bt, xt)
        state = state * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, y

    s0 = jnp.zeros((b, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)
    return (y + x.astype(jnp.float32) * D[:, None]).astype(x.dtype)


def ssd_chunked(x, dt, A, B, C, D, chunk: int = 256,
                return_final_state: bool = False):
    """Chunked SSD (Mamba-2 Alg. with block decomposition).

    Same signature/semantics as ``ssd_reference``; O(L/Q) sequential steps,
    each an MXU-friendly quadratic form over a Q-token chunk.

    ``return_final_state=True`` additionally returns the recurrence state
    after the last REAL token as (b, H, P, N) float32 — the decode-cache
    layout of ``init_mamba_cache`` — so a prefill can seed ``decode_step``
    without replaying the sequence.  (Padded chunk tails have dt == 0:
    decay exp(0) = 1 and a zero injection, so they leave the state
    untouched and the final scan carry IS the length-L state.)
    """
    b, L, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    nc = -(-L // Q)
    pad = nc * Q - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    f32 = jnp.float32
    xc = x.reshape(b, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(b, nc, Q, H).astype(f32)
    Bc = B.reshape(b, nc, Q, N).astype(f32)
    Cc = C.reshape(b, nc, Q, N).astype(f32)

    dA = dtc * A                                    # (b,nc,Q,H) log-decay
    cum = jnp.cumsum(dA, axis=2)                    # within-chunk cumulative
    total = cum[:, :, -1:, :]                       # (b,nc,1,H)

    # ---- intra-chunk (dual quadratic form) ----
    # M[i,j] = exp(cum_i - cum_j) for i >= j  (segment-sum mask)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (b,nc,Q,Q,H)
    idx = jnp.arange(Q)
    causal = idx[:, None] >= idx[None, :]
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)           # (b,nc,Q,Q)
    scores = scores[..., None] * Lmat * dtc[:, :, None, :, :]  # ×dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xc)

    # ---- chunk states ----
    # S_c = sum_j exp(total - cum_j) * dt_j * B_j ⊗ x_j   : (b,nc,H,N,P)
    decay_to_end = jnp.exp(total - cum)                      # (b,nc,Q,H)
    Sc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                    decay_to_end * dtc, Bc, xc)

    # ---- inter-chunk recurrence over nc chunks ----
    chunk_decay = jnp.exp(total[:, :, 0, :])                 # (b,nc,H)

    def chain(prev, inp):
        dec, s_local = inp                                   # (b,H),(b,H,N,P)
        new = prev * dec[..., None, None] + s_local
        return new, prev                                     # emit state *before* chunk

    s0 = jnp.zeros((b, H, N, P), f32)
    final_state, prev_states = jax.lax.scan(
        chain, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                    jnp.moveaxis(Sc, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (b,nc,H,N,P)

    # ---- inter-chunk contribution ----
    decay_from_start = jnp.exp(cum)                          # (b,nc,Q,H)
    y_inter = jnp.einsum("bcin,bchnp->bcihp",
                         Cc, prev_states) * decay_from_start[..., None]

    y = (y_intra + y_inter).reshape(b, nc * Q, H, P)[:, :L]
    y = (y + x.reshape(b, nc * Q, H, P)[:, :L] * D[:, None]) \
        .astype(jnp.float32).astype(x.dtype)
    if return_final_state:
        return y, jnp.moveaxis(final_state, -1, -2)          # (b,H,P,N)
    return y


# ----------------------------------------------------------------------
# Full mixer (projections + causal conv + SSD + gate)
# ----------------------------------------------------------------------
def _causal_conv(x, w, b):
    """x: (B, L, Cdim); w: (k, Cdim) depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def mamba_mixer(params, x, cfg, chunk: int = 0, return_cache: bool = False,
                cache_dtype=jnp.bfloat16):
    """x: (B, L, d_model) -> (B, L, d_model).

    ``return_cache=True`` returns ``(y, cache)`` where ``cache`` matches
    ``init_mamba_cache`` after L decode steps: the final SSD recurrence
    state plus the last ``conv_kernel - 1`` raw conv inputs (left-padded
    with the zeros the decode shift register starts from when L is short).
    """
    chunk = chunk or cfg.ssd_chunk or 256
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Bsz, L, _ = x.shape
    zxbcdt = dense(params["in_proj"], x)
    z, xs, Bv, Cv, dt = _split_proj(zxbcdt, H, P, N)
    conv_in = jnp.concatenate([xs, Bv, Cv], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"].astype(x.dtype),
                            params["conv_b"].astype(x.dtype))
    xs = conv_out[..., :H * P].reshape(Bsz, L, H, P)
    Bv = conv_out[..., H * P:H * P + N]
    Cv = conv_out[..., H * P + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y = ssd_chunked(xs, dt, A, Bv, Cv, params["D"].astype(jnp.float32),
                    chunk=chunk, return_final_state=return_cache)
    if return_cache:
        y, final_state = y
        k = params["conv_w"].shape[0]
        tail = conv_in[:, max(L - (k - 1), 0):, :].astype(cache_dtype)
        if L < k - 1:
            tail = jnp.pad(tail, ((0, 0), (k - 1 - L, 0), (0, 0)))
        cache = {"ssm": final_state, "conv": tail}
    y = y.reshape(Bsz, L, H * P)
    # gated RMSNorm (mamba2's norm-before-gate)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * params["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = dense(params["out_proj"], y)
    if return_cache:
        return out, cache
    return out


# ----------------------------------------------------------------------
# Decode (O(1) per token)
# ----------------------------------------------------------------------
def init_mamba_cache(batch: int, cfg, dtype=jnp.float32):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = H * P + 2 * N
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
    }


def mamba_decode_step(params, x, cache, cfg):
    """x: (B, 1, d_model); cache: {'ssm': (B,H,P,N), 'conv': (B,k-1,Cd)}."""
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Bsz = x.shape[0]
    zxbcdt = dense(params["in_proj"], x)[:, 0]      # (B, proj)
    z, xs, Bv, Cv, dt = _split_proj(zxbcdt, H, P, N)
    conv_in = jnp.concatenate([xs, Bv, Cv], axis=-1)    # (B, Cd)
    hist = jnp.concatenate([cache["conv"],
                            conv_in[:, None, :].astype(cache["conv"].dtype)],
                           axis=1)                      # (B, k, Cd)
    w = params["conv_w"].astype(hist.dtype)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w)
                           + params["conv_b"].astype(hist.dtype))
    xs = conv_out[..., :H * P].reshape(Bsz, H, P).astype(jnp.float32)
    Bv = conv_out[..., H * P:H * P + N].astype(jnp.float32)
    Cv = conv_out[..., H * P + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                             # (B,H)
    state = cache["ssm"] * dA[..., None, None] + \
        jnp.einsum("bh,bn,bhp->bhpn", dt, Bv, xs)
    y = jnp.einsum("bhpn,bn->bhp", state, Cv) + \
        xs * params["D"].astype(jnp.float32)[:, None]
    y = y.reshape(Bsz, H * P)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-6) * params["norm_scale"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(params["out_proj"], y[:, None, :])
    new_cache = {"ssm": state, "conv": hist[:, 1:, :]}
    return out, new_cache
