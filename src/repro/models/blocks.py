"""Decoder block variants: dense / moe / ssm / hybrid — train & decode paths.

Each block is a pure function of (params, x, ...) so layer stacks can be
``lax.scan``-ed over stacked params (keeps HLO compact for the 512-device
dry-run).  Per-layer heterogeneity (gemma-2 local/global windows, hymba
global layers) is passed as *data* (a per-layer window scalar), not
structure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (attention_block, decode_attention_block,
                        init_attention, init_kv_cache)
from .layers import init_mlp, init_rms_norm, mlp, rms_norm
from .mamba import (init_mamba, init_mamba_cache, mamba_decode_step,
                    mamba_mixer)
from .moe import init_moe, moe_layer

__all__ = ["init_block", "block_forward", "block_decode", "init_block_cache",
           "layer_windows"]

GLOBAL_WINDOW = jnp.iinfo(jnp.int32).max // 2   # "no window"


def layer_windows(cfg, num_layers=None):
    """Per-layer sliding-window sizes as an (L,) int32 array.

    gemma-2 style: with ``window_pattern`` p, every p-th layer is global;
    others use ``sliding_window``.  Without a pattern, all layers share
    ``sliding_window`` (or full attention when it is 0).
    """
    L = num_layers if num_layers is not None else cfg.num_layers
    if cfg.sliding_window <= 0:
        return jnp.full((L,), GLOBAL_WINDOW, jnp.int32)
    idx = jnp.arange(L)
    if cfg.global_layers:
        is_global = jnp.isin(idx, jnp.asarray(cfg.global_layers))
        return jnp.where(is_global, GLOBAL_WINDOW, cfg.sliding_window)
    if cfg.window_pattern > 0:
        is_global = (idx % cfg.window_pattern) == (cfg.window_pattern - 1)
        return jnp.where(is_global, GLOBAL_WINDOW, cfg.sliding_window)
    return jnp.full((L,), cfg.sliding_window, jnp.int32)


# ----------------------------------------------------------------------
def init_block(key, cfg, dtype=jnp.float32):
    """One layer's params; vmap over layer keys to build the stacked tree."""
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p = {"ln1": init_rms_norm(d, dtype)}
    t = cfg.arch_type
    if t in ("dense", "vlm", "audio", "moe", "hybrid", "encdec"):
        p["attn"] = init_attention(ks[0], d, cfg.num_heads, cfg.num_kv_heads,
                                   cfg.head_dim, dtype, qk_norm=cfg.qk_norm)
    if t in ("ssm", "hybrid"):
        p["mamba"] = init_mamba(ks[1], d, cfg.ssm_heads, cfg.ssm_head_dim,
                                cfg.ssm_state, cfg.conv_kernel, dtype)
    if t == "hybrid":
        p["beta_attn"] = jnp.ones((d,), dtype)
        p["beta_ssm"] = jnp.ones((d,), dtype)
        p["bn_attn"] = init_rms_norm(d, dtype)
        p["bn_ssm"] = init_rms_norm(d, dtype)
    if t == "moe":
        p["ln2"] = init_rms_norm(d, dtype)
        p["moe"] = init_moe(ks[2], d, cfg.num_experts, cfg.expert_d_ff, dtype)
    elif t != "ssm" and cfg.d_ff > 0:
        p["ln2"] = init_rms_norm(d, dtype)
        p["mlp"] = init_mlp(ks[3], d, cfg.d_ff, dtype)
    if cfg.post_norm:
        p["pn1"] = init_rms_norm(d, dtype)
        if "ln2" in p:
            p["pn2"] = init_rms_norm(d, dtype)
    return p


# ----------------------------------------------------------------------
def block_forward(params, x, positions, cfg, window=None,
                  collect_cache: bool = False, cache_dtype=jnp.bfloat16):
    """Training/prefill path. Returns (x, cache_or_None, aux_loss).

    With ``collect_cache`` the middle return is this layer's decode cache
    in ``init_block_cache`` layout (seq dim = prompt length for kv) —
    exactly the state L sequential ``block_decode`` calls would have
    produced.  Without it, the raw post-rope (k, v) tuple (training
    introspection) for attention archs, else None.
    """
    aux = jnp.zeros((), jnp.float32)
    kv = None
    blk_cache = {}
    t = cfg.arch_type
    h = rms_norm(params["ln1"], x, cfg.norm_eps)

    if t == "hybrid":
        attn_out, kv = attention_block(params["attn"], h, positions, cfg,
                                       window=window)
        ssm_out = mamba_mixer(params["mamba"], h, cfg,
                              return_cache=collect_cache,
                              cache_dtype=cache_dtype)
        if collect_cache:
            ssm_out, blk_cache["mamba"] = ssm_out
        attn_out = rms_norm(params["bn_attn"], attn_out, cfg.norm_eps) \
            * params["beta_attn"].astype(x.dtype)
        ssm_out = rms_norm(params["bn_ssm"], ssm_out, cfg.norm_eps) \
            * params["beta_ssm"].astype(x.dtype)
        mix = 0.5 * (attn_out + ssm_out)
        x = x + mix
    elif t == "ssm":
        out = mamba_mixer(params["mamba"], h, cfg,
                          return_cache=collect_cache,
                          cache_dtype=cache_dtype)
        if collect_cache:
            out, blk_cache["mamba"] = out
        x = x + out
    else:
        attn_out, kv = attention_block(params["attn"], h, positions, cfg,
                                       window=window)
        if cfg.post_norm:
            attn_out = rms_norm(params["pn1"], attn_out, cfg.norm_eps)
        x = x + attn_out

    if collect_cache and kv is not None:
        k, v = kv
        blk_cache["kv"] = {"k": k.astype(cache_dtype),
                           "v": v.astype(cache_dtype)}
    if collect_cache:
        kv = blk_cache

    if "moe" in params:
        h2 = rms_norm(params["ln2"], x, cfg.norm_eps)
        moe_out, aux = moe_layer(params["moe"], h2, cfg)
        x = x + moe_out
    elif "mlp" in params:
        h2 = rms_norm(params["ln2"], x, cfg.norm_eps)
        mlp_out = mlp(params["mlp"], h2, cfg.activation,
                      megatron=cfg.mlp_megatron)
        if cfg.post_norm:
            mlp_out = rms_norm(params["pn2"], mlp_out, cfg.norm_eps)
        x = x + mlp_out
    return x, kv, aux


# ----------------------------------------------------------------------
def init_block_cache(batch, seq_len, cfg, dtype=jnp.bfloat16):
    """Per-layer decode cache (stacked over layers by the caller)."""
    c = {}
    t = cfg.arch_type
    if t != "ssm":
        c["kv"] = init_kv_cache(batch, seq_len, cfg.num_kv_heads,
                                cfg.head_dim, dtype)
    if t in ("ssm", "hybrid"):
        c["mamba"] = init_mamba_cache(batch, cfg, dtype)
    return c


def block_decode(params, x, cache, cache_len, cfg, window=None):
    """Single-token decode. Returns (x, new_cache)."""
    new_cache = dict(cache)
    t = cfg.arch_type
    h = rms_norm(params["ln1"], x, cfg.norm_eps)

    if t == "hybrid":
        attn_out, new_cache["kv"] = decode_attention_block(
            params["attn"], h, cache["kv"], cache_len, cfg, window=window)
        ssm_out, new_cache["mamba"] = mamba_decode_step(
            params["mamba"], h, cache["mamba"], cfg)
        attn_out = rms_norm(params["bn_attn"], attn_out, cfg.norm_eps) \
            * params["beta_attn"].astype(x.dtype)
        ssm_out = rms_norm(params["bn_ssm"], ssm_out, cfg.norm_eps) \
            * params["beta_ssm"].astype(x.dtype)
        x = x + 0.5 * (attn_out + ssm_out)
    elif t == "ssm":
        out, new_cache["mamba"] = mamba_decode_step(
            params["mamba"], h, cache["mamba"], cfg)
        x = x + out
    else:
        attn_out, new_cache["kv"] = decode_attention_block(
            params["attn"], h, cache["kv"], cache_len, cfg, window=window)
        if cfg.post_norm:
            attn_out = rms_norm(params["pn1"], attn_out, cfg.norm_eps)
        x = x + attn_out

    if "moe" in params:
        h2 = rms_norm(params["ln2"], x, cfg.norm_eps)
        moe_out, _ = moe_layer(params["moe"], h2, cfg)
        x = x + moe_out
    elif "mlp" in params:
        h2 = rms_norm(params["ln2"], x, cfg.norm_eps)
        mlp_out = mlp(params["mlp"], h2, cfg.activation)
        if cfg.post_norm:
            mlp_out = rms_norm(params["pn2"], mlp_out, cfg.norm_eps)
        x = x + mlp_out
    return x, new_cache
