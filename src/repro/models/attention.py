"""Grouped-query attention: chunked (flash-style) training path + KV-cache
decode path.

The training path is a pure-jnp blockwise online-softmax attention — the
same algorithm the Pallas kernel (kernels/flash_attention.py) implements on
TPU; here it keeps peak memory at O(S * chunk) instead of O(S^2) so 32k
prefill lowers with sane memory_analysis.  Supports GQA, causal masking,
sliding windows (as data, so gemma-2's local/global alternation can live
inside one lax.scan over layers) and gemma-2 attn logit soft-capping.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.shardlib import constrain

from .layers import apply_rope, dense, init_dense, softcap

__all__ = ["init_attention", "attention_block", "decode_attention_block",
           "init_kv_cache", "chunked_attention"]

NEG_INF = -1e30


def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype=jnp.float32, qk_norm: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d_model, num_heads * head_dim, dtype),
        "wk": init_dense(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "wv": init_dense(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "wo": init_dense(ks[3], num_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = {"scale": jnp.ones((head_dim,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((head_dim,), dtype)}
    return p


def _headwise_rms(x, scale, eps=1e-6):
    v = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(v + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------
# Blockwise online-softmax attention (training/prefill)
# ----------------------------------------------------------------------
def chunked_attention(q, k, v, *, causal: bool = True,
                      window=None, attn_softcap: float = 0.0,
                      q_chunk: int = 512, k_chunk: int = 1024,
                      q_offset: int = 0, block_skip: bool = False):
    """q: (B, Sq, H, D);  k, v: (B, Sk, KH, D)  with H = KH * G.

    ``window``: None/0 = full attention; int or traced scalar = sliding
    window (token i attends to j in (i-window, i]).
    Returns (B, Sq, H, D).
    """
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // k_chunk)
    # pad to multiples
    pq, pk = nq * q_chunk - Sq, nk * k_chunk - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))

    qg = q.reshape(B, nq, q_chunk, KH, G, D)
    kg = k.reshape(B, nk, k_chunk, KH, D)
    vg = v.reshape(B, nk, k_chunk, KH, D)

    win = None
    if window is not None:
        win = jnp.asarray(window, jnp.int32)

    def q_block(qi, q_blk):
        # online softmax over k blocks
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            acc, m, lsum = carry
            ki, k_blk, v_blk = inputs
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            # scores: (B, q_chunk, KH, G, k_chunk)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if attn_softcap:
                s = softcap(s, attn_softcap)
            mask = (k_pos[None, :] <= Sk - 1)  # padded kv
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if win is not None:
                mask = mask & (q_pos[:, None] - k_pos[None, :] < win)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = lsum * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, q_chunk, KH, G, D), jnp.float32)
        m0 = jnp.full((B, q_chunk, KH, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, KH, G), jnp.float32)
        ks_idx = jnp.arange(nk)
        kgs = jnp.moveaxis(kg, 1, 0)
        vgs = jnp.moveaxis(vg, 1, 0)
        inner_step = jax.checkpoint(kv_step)
        step = inner_step
        if block_skip:
            # skip kv blocks fully outside the (causal, window) band —
            # lax.cond with a scalar predicate stays a real branch, so
            # masked-out blocks cost ~0 on TPU (§Perf hc3)
            def guarded(carry, inputs):
                ki = inputs[0]
                k_first = ki * k_chunk
                k_last = k_first + k_chunk - 1
                q_first = q_offset + qi * q_chunk
                q_last = q_first + q_chunk - 1
                live = jnp.asarray(True)
                if causal:
                    live = live & (k_first <= q_last)
                if win is not None:
                    live = live & (k_last > q_first - win)
                return jax.lax.cond(live, inner_step,
                                    lambda c, _: (c, None), carry, inputs)
            step = guarded
        (acc, m, lsum), _ = jax.lax.scan(step, (acc0, m0, l0),
                                         (ks_idx, kgs, vgs))
        out = acc / jnp.maximum(lsum, 1e-20)[..., None]
        return out.astype(q.dtype)

    qi_idx = jnp.arange(nq)
    qgs = jnp.moveaxis(qg, 1, 0)                   # (nq, B, qc, KH, G, D)
    outs = jax.lax.map(lambda args: q_block(*args), (qi_idx, qgs))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq]


# ----------------------------------------------------------------------
# Full attention block (projections + rope + attention)
# ----------------------------------------------------------------------
def attention_block(params, x, positions, cfg, *, window=None,
                    causal: bool = True, kv_source=None):
    """x: (B, S, d_model). kv_source: cross-attention memory (B, Sk, d)."""
    B, S, _ = x.shape
    H, KH, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if kv_source is None else kv_source
    q = dense(params["wq"], x).reshape(B, S, H, D)
    k = dense(params["wk"], src).reshape(B, src.shape[1], KH, D)
    v = dense(params["wv"], src).reshape(B, src.shape[1], KH, D)
    if "q_norm" in params:
        q = _headwise_rms(q, params["q_norm"]["scale"])
        k = _headwise_rms(k, params["k_norm"]["scale"])
    if kv_source is None:  # self-attention: rotary on both
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_kv_gather:
        # ring-attention-lite (§Perf hc1 H6): q and the attention output
        # stay sequence-sharded (no x/out seq transitions); only K/V —
        # kv_dim << d_model under GQA — are gathered to full sequence.
        q = constrain(q, "batch", "seq", None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
    else:
        # SP<->TP boundary: attention runs head-sharded so its inner chunk
        # loops are collective-free (the all-to-all lives here, per layer)
        q = constrain(q, "batch", None, "heads", None)
        k = constrain(k, "batch", None, "kv_heads", None)
        v = constrain(v, "batch", None, "kv_heads", None)
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            attn_softcap=cfg.attn_softcap,
                            q_chunk=cfg.attn_q_chunk or 512,
                            k_chunk=cfg.attn_k_chunk or 1024,
                            block_skip=cfg.attn_block_skip)
    out = constrain(out, "batch", "seq", None, None) if cfg.attn_kv_gather \
        else constrain(out, "batch", None, "heads", None)
    return dense(params["wo"], out.reshape(B, S, H * D)), (k, v)


# ----------------------------------------------------------------------
# Decode path (1 new token against a KV cache)
# ----------------------------------------------------------------------
def init_kv_cache(batch: int, seq_len: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, seq_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, seq_len, num_kv_heads, head_dim), dtype),
    }


def decode_attention_block(params, x, cache, cache_len, cfg, *, window=None):
    """x: (B, 1, d_model); cache k/v: (B, S, KH, D); cache_len: count of
    valid tokens already in the cache — a scalar int, or a (B,) vector of
    PER-ROW counts (continuous batching: each slot decodes at its own
    position).  Returns (out, new_cache).
    """
    B, _, _ = x.shape
    H, KH, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KH
    S = cache["k"].shape[1]
    lens = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))
    pos = lens[:, None]
    q = dense(params["wq"], x).reshape(B, 1, H, D)
    k = dense(params["wk"], x).reshape(B, 1, KH, D)
    v = dense(params["wv"], x).reshape(B, 1, KH, D)
    if "q_norm" in params:
        q = _headwise_rms(q, params["q_norm"]["scale"])
        k = _headwise_rms(k, params["k_norm"]["scale"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    # per-row cache write at each row's own position (a one-hot select
    # instead of dynamic_update_slice, which only takes batch-shared
    # offsets); a row whose length already reached S writes nothing
    k_pos = jnp.arange(S)
    write = (k_pos[None, :] == lens[:, None])[:, :, None, None]
    ck = jnp.where(write, k.astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(write, v.astype(cache["v"].dtype), cache["v"])

    qg = q.reshape(B, KH, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, ck,
                   preferred_element_type=jnp.float32) / jnp.sqrt(D)
    if cfg.attn_softcap:
        s = softcap(s, cfg.attn_softcap)
    mask = k_pos[None, :] <= lens[:, None]
    if window is not None:
        mask = mask & (lens[:, None] - k_pos[None, :]
                       < jnp.asarray(window, jnp.int32))
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(cv.dtype), cv,
                   preferred_element_type=jnp.float32)
    out = dense(params["wo"], o.reshape(B, 1, H * D).astype(x.dtype))
    return out, {"k": ck, "v": cv}
