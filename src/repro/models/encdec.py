"""Encoder-decoder transformer (SeamlessM4T-v2 backbone, arXiv:2308.11596).

The speech frontend (mel + conformer feature extractor) is stubbed per the
assignment carve-out: the encoder consumes precomputed frame embeddings
(B, S_enc, d_model).  Encoder = non-causal self-attention blocks; decoder =
causal self-attention + cross-attention + gated MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import (attention_block, decode_attention_block,
                        init_attention, init_kv_cache)
from .layers import (dense, embed, init_dense, init_embedding, init_mlp,
                     init_rms_norm, mlp, rms_norm)
from .lm import chunked_cross_entropy

__all__ = ["init_encdec_params", "encdec_forward", "encdec_loss_fn",
           "init_encdec_cache", "encdec_decode_step", "encode"]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _init_enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rms_norm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg.d_model, cfg.num_heads,
                               cfg.num_kv_heads, cfg.head_dim, dtype),
        "ln2": init_rms_norm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rms_norm(cfg.d_model, dtype),
        "self_attn": init_attention(k1, cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, cfg.head_dim, dtype),
        "lnx": init_rms_norm(cfg.d_model, dtype),
        "cross_attn": init_attention(k2, cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.head_dim, dtype),
        "ln2": init_rms_norm(cfg.d_model, dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_encdec_params(key, cfg):
    pdt = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
    ke, kd, kt, kp = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.num_encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "frontend_proj": init_dense(kp, cfg.d_model, cfg.d_model, pdt),
        "embed": init_embedding(kt, cfg.vocab_size, cfg.d_model, pdt),
        "encoder": jax.vmap(lambda k: _init_enc_layer(k, cfg, pdt))(enc_keys),
        "enc_norm": init_rms_norm(cfg.d_model, pdt),
        "decoder": jax.vmap(lambda k: _init_dec_layer(k, cfg, pdt))(dec_keys),
        "final_norm": init_rms_norm(cfg.d_model, pdt),
    }


def embed_tokens(params, tokens, cfg):
    return embed(params["embed"], tokens).astype(_dtype(cfg))


def encode(params, frame_embeds, cfg, scan_unroll=False):
    """frame_embeds: (B, S_enc, d_model) -> encoder memory."""
    dt = _dtype(cfg)
    x = dense(params["frontend_proj"], frame_embeds.astype(dt))
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(x, lp):
        h = rms_norm(lp["ln1"], x, cfg.norm_eps)
        a, _ = attention_block(lp["attn"], h, pos, cfg, causal=False)
        x = x + a
        h = rms_norm(lp["ln2"], x, cfg.norm_eps)
        return x + mlp(lp["mlp"], h, cfg.activation), None

    x, _ = jax.lax.scan(body, x, params["encoder"],
                        unroll=cfg.num_encoder_layers if scan_unroll else 1)
    return rms_norm(params["enc_norm"], x, cfg.norm_eps)


def _decode_stack(params, x, memory, cfg, scan_unroll=False):
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(x, lp):
        h = rms_norm(lp["ln1"], x, cfg.norm_eps)
        a, _ = attention_block(lp["self_attn"], h, pos, cfg, causal=True)
        x = x + a
        h = rms_norm(lp["lnx"], x, cfg.norm_eps)
        c, _ = attention_block(lp["cross_attn"], h, pos, cfg, causal=False,
                               kv_source=memory)
        x = x + c
        h = rms_norm(lp["ln2"], x, cfg.norm_eps)
        return x + mlp(lp["mlp"], h, cfg.activation), None

    x, _ = jax.lax.scan(body, x, params["decoder"],
                        unroll=cfg.num_layers if scan_unroll else 1)
    return rms_norm(params["final_norm"], x, cfg.norm_eps)


def encdec_forward(params, frame_embeds, tokens, cfg, scan_unroll=False):
    memory = encode(params, frame_embeds, cfg, scan_unroll)
    x = embed(params["embed"], tokens).astype(_dtype(cfg))
    return _decode_stack(params, x, memory, cfg, scan_unroll)


def encdec_loss_fn(params, batch, cfg, scan_unroll=False):
    hidden = encdec_forward(params, batch["frontend_embeds"],
                            batch["tokens"], cfg, scan_unroll)
    ce = chunked_cross_entropy(hidden, params["embed"]["table"],
                               batch["labels"], cfg)
    return ce, {"ce": ce, "aux": jnp.zeros(())}


# ----------------------------------------------------------------------
# Serving: self-attn KV cache + precomputed cross K/V
# ----------------------------------------------------------------------
def init_encdec_cache(cfg, batch: int, max_seq: int, enc_len: int,
                      dtype=jnp.bfloat16):
    def one(_):
        return {
            "kv": init_kv_cache(batch, max_seq, cfg.num_kv_heads,
                                cfg.head_dim, dtype),
            "cross_k": jnp.zeros((batch, enc_len, cfg.num_kv_heads,
                                  cfg.head_dim), dtype),
            "cross_v": jnp.zeros((batch, enc_len, cfg.num_kv_heads,
                                  cfg.head_dim), dtype),
        }
    return jax.vmap(one)(jnp.arange(cfg.num_layers))


def encdec_decode_step(params, cache, cache_len, tokens, cfg,
                       scan_unroll=False):
    """One decoder token against self-cache + precomputed cross K/V."""
    dt = _dtype(cfg)
    x = embed(params["embed"], tokens).astype(dt)
    H, KH, D = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KH

    def body(x, layer_in):
        lp, lc = layer_in
        new_c = dict(lc)
        h = rms_norm(lp["ln1"], x, cfg.norm_eps)
        a, new_c["kv"] = decode_attention_block(lp["self_attn"], h,
                                                lc["kv"], cache_len, cfg)
        x = x + a
        # cross-attention over the full (precomputed) encoder memory
        h = rms_norm(lp["lnx"], x, cfg.norm_eps)
        B = x.shape[0]
        q = dense(lp["cross_attn"]["wq"], h).reshape(B, KH, G, D)
        s = jnp.einsum("bhgd,bkhd->bhgk", q, lc["cross_k"],
                       preferred_element_type=jnp.float32) / jnp.sqrt(D)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(lc["cross_v"].dtype),
                       lc["cross_v"])
        c = dense(lp["cross_attn"]["wo"], o.reshape(B, 1, H * D).astype(dt))
        x = x + c
        h = rms_norm(lp["ln2"], x, cfg.norm_eps)
        x = x + mlp(lp["mlp"], h, cfg.activation)
        return x, new_c

    x, new_cache = jax.lax.scan(body, x, (params["decoder"], cache),
                                unroll=cfg.num_layers if scan_unroll else 1)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ params["embed"]["table"].astype(dt).T
    return logits.astype(jnp.float32), new_cache
