"""End-to-end training driver: BPT-CNN outer layer over any assigned arch.

CPU-scale by default (reduced configs + small synthetic corpus) so the same
driver that launches on a pod runs as a demo here (`pip install -e .`
first; bare checkouts can prefix `PYTHONPATH=src`):

    python -m repro.launch.train --arch yi-6b --reduced \
        --outer agwu --partitioning idpa --rounds 8

``--device-outer`` places the node axis on a real `nodes` device mesh
(``--mesh nodes4`` to name a `launch.mesh.MESHES` member; emulate with
XLA_FLAGS=--xla_force_host_platform_device_count=4), ``--uneven-batches``
realizes IDPA-proportional per-node loads, and ``--engine`` selects the
outer-layer execution engine by name (`repro.core.engine.ENGINES`).  The
outer layer (IDPA + AGWU/SGWU — the paper's contribution) runs with real
jitted steps on CPU.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpointing import checkpoint
from repro.core.bpt_trainer import BPTTrainer, TrainHooks
from repro.core.engine import ENGINES, engine_config
from repro.core.faults import FaultSchedule
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset, host_batch, pack_sequences
from repro.data.synthetic import lm_corpus
from repro.launch import runtime
from repro.models import lm
from repro.models.frontends import random_frontend_embeds


def build_lm_dataset(cfg, seq_len: int, num_rows: int, nodes: int,
                     batches: int, partitioning: str, frequencies):
    corpus = lm_corpus(num_rows * seq_len + 1, cfg.vocab_size, seed=0)
    rows = pack_sequences(corpus, seq_len)
    return IDPADataset({"rows": rows}, num_nodes=nodes, batches=batches,
                       frequencies=frequencies, partitioning=partitioning)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=configs.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--outer", default="agwu",
                    choices=["agwu", "sgwu", "sync"])
    ap.add_argument("--engine", default="", choices=sorted(ENGINES),
                    help="select the execution engine by name (overrides "
                    "--outer/--device-outer)")
    ap.add_argument("--device-outer", action="store_true",
                    help="shard the node axis over a real `nodes` device "
                    "mesh (one node per device; falls back to the fused "
                    "vmap emulation when fewer than --nodes devices exist)")
    ap.add_argument("--mesh", default="",
                    help="named launch.mesh.MESHES entry for the node axis "
                    "(e.g. nodes4; needs a `nodes` axis of size --nodes); "
                    "empty = auto 1-D nodes mesh")
    ap.add_argument("--uneven-batches", action="store_true",
                    help="IDPA-proportional per-node batch loads "
                    "(padded+masked stripes; needs the SGWU stacked paths)")
    ap.add_argument("--partitioning", default="idpa",
                    choices=["idpa", "udpa"])
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--rows", type=int, default=512)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="save a weight checkpoint AND a resumable "
                    "train-state checkpoint into --ckpt-dir every N merge "
                    "events (0 = only the final weights)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest train-state checkpoint from "
                    "--ckpt-dir before the first round (a fresh dir just "
                    "starts from scratch — safe to always pass)")
    ap.add_argument("--faults", default="",
                    help="fault schedule: comma-separated "
                    "kind:node@event[xfactor] atoms, e.g. "
                    "'fail:1@3,rejoin:1@6,slow:2@4x2.5' — node churn "
                    "injected into the outer layer (core.faults)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cache_dir = runtime.maybe_enable_compilation_cache()
    if cache_dir:
        print(f"[train] compilation cache: {cache_dir}")

    cfg = configs.get_reduced(args.arch) if args.reduced \
        else configs.get_config(args.arch)
    if cfg.arch_type == "encdec":
        raise SystemExit("use examples/train_bpt_cnn.py or a decoder arch "
                         "for the LM demo driver")
    print(f"[train] {cfg.name} ({cfg.arch_type}) reduced={args.reduced} "
          f"outer={args.outer} partitioning={args.partitioning}")

    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    n_params = sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(params))
    print(f"[train] params: {n_params/1e6:.1f}M")

    frontend = None
    if cfg.frontend:
        frontend = random_frontend_embeds(key, cfg, args.batch_size)

    def loss_fn(p, batch):
        rows = batch["rows"]
        b = host_batch(rows)
        if "mask" in batch:
            # uneven stripes: padded rows (mask 0) carry no loss — label
            # them -1, which chunked_cross_entropy excludes from the mean
            b["labels"] = jnp.where(batch["mask"][:, None] > 0,
                                    b["labels"], -1)
        if frontend is not None:
            b["frontend_embeds"] = frontend[:rows.shape[0]]
        return lm.loss_fn(p, b, cfg)

    speeds = 1.0 + 0.4 * np.arange(args.nodes) / max(args.nodes - 1, 1)
    ds = build_lm_dataset(cfg, args.seq_len, args.rows, args.nodes,
                          batches=min(4, args.rounds),
                          partitioning=args.partitioning,
                          frequencies=1.0 / speeds)
    common = dict(learning_rate=args.lr, partitioning=args.partitioning,
                  outer_nodes=args.nodes, local_steps=args.local_steps,
                  warmup_steps=5, seed=args.seed,
                  total_steps=args.rounds * args.local_steps * args.nodes,
                  mesh_name=args.mesh, uneven_batches=args.uneven_batches)
    if args.engine:     # engine selected by name through the engine API
        tc = TrainConfig(**engine_config(args.engine, **common))
    else:
        tc = TrainConfig(outer_strategy=args.outer,
                         device_outer=args.device_outer, **common)
    faults = FaultSchedule.from_spec(args.faults, num_nodes=args.nodes) \
        if args.faults else None
    trainer = BPTTrainer(loss_fn, params, ds, tc,
                         batch_size=args.batch_size, speed_factors=speeds,
                         fault_schedule=faults)
    hooks = None
    if args.ckpt_every:
        if not args.ckpt_dir:
            raise SystemExit("--ckpt-every needs --ckpt-dir")
        hooks = TrainHooks(checkpoint_every=args.ckpt_every,
                           checkpoint_dir=args.ckpt_dir,
                           resume=args.resume)
    elif args.resume:
        raise SystemExit("--resume needs --ckpt-every and --ckpt-dir")
    t0 = time.time()
    report = trainer.train(args.rounds, hooks)
    wall = time.time() - t0
    if report.fallback:
        print(f"[train] engine fallback: {report.fallback}")
    print(f"[train] done in {wall:.1f}s wall; report:")
    print(json.dumps(report.summary(), indent=2, default=str))
    if report.losses:
        first, last = report.losses[0], report.losses[-1]
        print(f"[train] loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    else:
        # --resume from a state checkpoint of an already-finished run:
        # nothing left to train, no new events
        print("[train] resumed past the final round; no new rounds ran")
    if args.ckpt_dir and report.losses:
        # last_event, not steps: on a resumed run, steps counts only the
        # events this process produced and would mislabel the checkpoint
        path = checkpoint.save(args.ckpt_dir, report.final_params,
                               step=report.last_event,
                               metadata={"arch": cfg.name})
        print(f"[train] checkpoint: {path}")
    return report


if __name__ == "__main__":
    main()
