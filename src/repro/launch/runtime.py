"""Process-level runtime knobs shared by drivers and benchmarks.

Currently one knob: the persistent XLA compilation cache (the ROADMAP
perf-flywheel item).  ON BY DEFAULT for drivers and benchmarks — repeat
runs of the same driver skip recompiles entirely because identical HLO
hits the on-disk cache instead of XLA.  ``REPRO_COMPILATION_CACHE``
overrides: a path relocates the cache, ``off`` (or ``0``) disables it.
Tests never call ``maybe_enable_compilation_cache``, so the suite keeps
its hermetic no-cache behavior.
"""
from __future__ import annotations

import os

__all__ = ["maybe_enable_compilation_cache", "default_cache_dir"]


def default_cache_dir() -> str:
    """XDG-style default cache location (``~/.cache`` unless overridden)."""
    base = os.environ.get("XDG_CACHE_HOME",
                          os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(base, "repro", "xla")


def maybe_enable_compilation_cache() -> str:
    """Enable jax's persistent compilation cache (default ON).

    Returns the cache directory actually enabled — the
    ``REPRO_COMPILATION_CACHE`` path when set, ``default_cache_dir()``
    when unset, or "" when the knob is ``off``/``0``.  Safe to call more
    than once and before/after other jax work; the directory is created
    if missing.
    """
    knob = os.environ.get("REPRO_COMPILATION_CACHE", "")
    if knob.lower() in ("off", "0"):
        return ""
    path = knob or default_cache_dir()
    from jax.experimental.compilation_cache import compilation_cache as cc
    os.makedirs(path, exist_ok=True)
    if hasattr(cc, "set_cache_dir"):
        cc.set_cache_dir(path)
    else:                       # older jax spelling
        cc.initialize_cache(path)
    return path
