"""Process-level runtime knobs shared by drivers and benchmarks.

Currently one knob: the persistent XLA compilation cache.  Setting
``REPRO_COMPILATION_CACHE=<dir>`` makes repeat runs of the same driver /
benchmark skip recompiles entirely (the ROADMAP perf-flywheel item) —
identical HLO hits the on-disk cache instead of XLA.  Off by default:
tests and one-shot runs keep their hermetic no-cache behavior.
"""
from __future__ import annotations

import os

__all__ = ["maybe_enable_compilation_cache"]


def maybe_enable_compilation_cache() -> str:
    """Enable jax's persistent compilation cache when the env knob is set.

    Returns the cache directory actually enabled ("" when the knob is
    unset).  Safe to call more than once and before/after other jax work;
    the directory is created if missing.
    """
    path = os.environ.get("REPRO_COMPILATION_CACHE", "")
    if not path:
        return ""
    from jax.experimental.compilation_cache import compilation_cache as cc
    os.makedirs(path, exist_ok=True)
    if hasattr(cc, "set_cache_dir"):
        cc.set_cache_dir(path)
    else:                       # older jax spelling
        cc.initialize_cache(path)
    return path
