"""Recompute roofline rows in experiments/dryrun/*.json from the stored raw
calibration data (no recompilation) — used when the roofline formulas /
correction factors change after a sweep has already run.
"""
from __future__ import annotations

import glob
import json
import sys

from repro import configs
from repro.launch import roofline
from repro.launch.dryrun import _attn_score_bytes


def refresh(path_glob: str = "experiments/dryrun/*.json") -> int:
    n = 0
    for fn in sorted(glob.glob(path_glob)):
        data = json.load(open(fn))
        if "calibrated" not in data:
            continue
        cfg = configs.get_config(data["arch"], data.get("variant", ""))
        shape = configs.get_shape(data["shape"])
        cal = data["calibrated"]
        score_corr = _attn_score_bytes(cfg, shape)
        bytes_flash = max(cal["bytes"] - score_corr, 0.0)
        rep = roofline.RooflineReport(
            arch=data["arch"], shape=data["shape"], mesh=data["mesh"],
            chips=data["chips"], hlo_flops=cal["flops"],
            hlo_bytes=bytes_flash, coll_bytes=cal["coll_bytes"],
            coll_detail=cal.get("coll_counts_L2", {}),
            model_flops_=roofline.model_flops(cfg, shape),
            per_device_hbm=data["memory_analysis"]["temp_size_in_bytes"]
            + data["memory_analysis"]["argument_size_in_bytes"])
        row = rep.row()
        hw = roofline.HW()
        row["memory_naive_ms"] = round(
            cal["bytes"] / (data["chips"] * hw.hbm_bw) * 1e3, 3)
        row["memory_flash_ms"] = row["memory_ms"]
        data["attn_score_bytes_corr"] = score_corr
        data["roofline"] = row
        with open(fn, "w") as f:
            json.dump(data, f, indent=1)
        n += 1
    return n


if __name__ == "__main__":
    glob_arg = sys.argv[1] if len(sys.argv) > 1 else \
        "experiments/dryrun/*.json"
    print(f"refreshed {refresh(glob_arg)} artifacts")
