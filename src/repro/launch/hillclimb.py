import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-hillclimb driver: evaluate one (arch x shape x mesh) with config
overrides and print/record the roofline row.

    PYTHONPATH=src python -m repro.launch.hillclimb --arch gemma2-27b \
        --shape train_4k --mesh pod --tag hc1a \
        --set bf16_params_compute=True --set mlp_megatron=True
"""
import argparse
import dataclasses
import json

from repro import configs
from repro.launch import dryrun


def parse_value(v: str):
    if v in ("True", "False"):
        return v == "True"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--variant", default="")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE", help="ModelConfig overrides")
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, args.variant)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    print(f"[hillclimb:{args.tag}] {args.arch} x {args.shape} x {args.mesh} "
          f"overrides={overrides}")
    res = dryrun.lower_and_compile(args.arch, args.shape, args.mesh,
                                   remat=not args.no_remat,
                                   cfg_override=cfg)
    res["overrides"] = overrides
    fn = dryrun.save_result(res, tag=args.tag)
    print(f"  -> {fn}")
    print(json.dumps(res["roofline"], indent=1))


if __name__ == "__main__":
    main()
