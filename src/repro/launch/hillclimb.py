"""Perf-hillclimb driver: a thin search loop over planner candidates.

Two modes, both scored by the same roofline terms:

* override mode (the historical driver): evaluate one (arch x shape x
  mesh) with ModelConfig overrides and print/record the roofline row.

      python -m repro.launch.hillclimb --arch gemma2-27b \
          --shape train_4k --mesh pod --tag hc1a \
          --set bf16_params_compute=True --set mlp_megatron=True

* plan mode (the CNN's 2-D hybrid mesh): enumerate ``(nodes, model)``
  axis splits of the device budget, score each with
  ``core.planner.plan_for_axes`` (per-layer inner cost) plus the Eq. 7
  merge all-reduce amortized over the local steps, and print the ranked
  candidates.  The search IS the planner — this loop owns no cost model
  of its own.

      python -m repro.launch.hillclimb --plan \
          --cnn case1 --devices 8 --batch-size 32

(``pip install -e .`` first; bare checkouts can prefix ``PYTHONPATH=src``.)

XLA_FLAGS is only touched under ``__main__`` (never on import), and any
pre-existing value is appended to, not clobbered.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os


def parse_value(v: str):
    if v in ("True", "False"):
        return v == "True"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def _axis_splits(budget: int):
    """Power-of-2 ``(nodes, model)`` splits fitting the device budget."""
    out = []
    n = 1
    while n <= budget:
        k = 1
        while n * k <= budget:
            out.append((n, k))
            k *= 2
        n *= 2
    return out


def plan_search(cnn: str, devices: int, batch_size: int,
                local_steps: int = 2) -> list[dict]:
    """Rank hybrid-mesh candidates for a CNN config by total round cost.

    Per candidate: the planner's per-layer inner cost (already / model
    shards), plus the ring all-reduce of one weight replica over
    ``nodes`` (the Eq. 7 merge) amortized over the local steps.  Ranked
    by cost per GLOBAL sample — a step processes ``nodes * B`` samples,
    so outer data parallelism's throughput counts against its merge
    traffic instead of every split losing to (1, 1).
    """
    from repro.core import planner
    from repro.launch.roofline import HW
    from repro.models.cnn import make_case

    cfg = make_case(cnn)
    hw = HW()
    rows = []
    for nodes, model in _axis_splits(devices):
        try:
            plan = planner.plan_for_axes(cfg, nodes=nodes, model=model,
                                         batch_size=batch_size)
        except ValueError:
            continue
        wbytes = planner.network_param_bytes(cfg)
        merge = 2.0 * (nodes - 1) / nodes * wbytes / hw.ici_bw \
            if nodes > 1 else 0.0
        cost = plan.total_cost_s + merge / max(local_steps, 1)
        rows.append({
            "nodes": nodes, "model": model, "family": plan.family,
            "inner_cost_s": plan.total_cost_s,
            "merge_cost_s_per_step": merge / max(local_steps, 1),
            "step_cost_s": cost,
            "cost_per_sample_s": cost / (nodes * batch_size),
            "layers": [{"name": lp.name, "dim": lp.parallel_dim,
                        "tile": lp.tile} for lp in plan.layers],
        })
    rows.sort(key=lambda r: r["cost_per_sample_s"])
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan", action="store_true",
                    help="rank (nodes, model) hybrid-mesh splits for a CNN")
    ap.add_argument("--cnn", default="case1",
                    help="Table 2 case name (plan mode)")
    ap.add_argument("--devices", type=int, default=8,
                    help="device budget to split (plan mode)")
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--tag")
    ap.add_argument("--variant", default="")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VALUE", help="ModelConfig overrides")
    args = ap.parse_args(argv)

    if args.plan:
        rows = plan_search(args.cnn, args.devices, args.batch_size,
                           args.local_steps)
        print(f"[hillclimb:plan] {args.cnn} over {args.devices} devices "
              f"B={args.batch_size}")
        print(json.dumps(rows, indent=1))
        return

    if not (args.arch and args.shape and args.tag):
        ap.error("override mode needs --arch, --shape and --tag "
                 "(or use --plan)")
    from repro import configs
    from repro.launch import dryrun
    cfg = configs.get_config(args.arch, args.variant)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    print(f"[hillclimb:{args.tag}] {args.arch} x {args.shape} x {args.mesh} "
          f"overrides={overrides}")
    res = dryrun.lower_and_compile(args.arch, args.shape, args.mesh,
                                   remat=not args.no_remat,
                                   cfg_override=cfg)
    res["overrides"] = overrides
    fn = dryrun.save_result(res, tag=args.tag)
    print(f"  -> {fn}")
    print(json.dumps(res["roofline"], indent=1))


if __name__ == "__main__":
    # append, never clobber, and only when the caller didn't already
    # force a device count — and only under __main__, so importing this
    # module can't poison another process's XLA options
    _flag = "--xla_force_host_platform_device_count=512"
    _prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _prev:
        os.environ["XLA_FLAGS"] = (_prev + " " + _flag).strip()
    main()
