"""Mesh construction for single-pod and multi-pod deployments.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  The dry-run gives jax 512 placeholder host devices; real
deployments get the same shapes from actual TPU topologies.
"""
from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_mesh", "make_nodes_mesh",
           "make_hybrid_mesh", "data_axes", "MESHES"]

MESHES = {
    "pod": ((16, 16), ("data", "model")),               # 256 chips (v5e pod)
    "multipod": ((2, 16, 16), ("pod", "data", "model")),  # 512 chips
    # reduced meshes for in-test dry-runs (subprocess with 8/16 devices)
    "tiny": ((2, 2), ("data", "model")),
    "tiny3d": ((2, 2, 2), ("pod", "data", "model")),
    # `nodes` family: 1-D meshes for the device-sharded BPT outer layer —
    # one device per computing node (the paper's m physical nodes).
    "nodes2": ((2,), ("nodes",)),
    "nodes4": ((4,), ("nodes",)),
    "nodes8": ((8,), ("nodes",)),
    "nodes16": ((16,), ("nodes",)),
    # `nodesNxmodelK` family: 2-D hybrid meshes — the paper's outer data
    # parallelism on `nodes` (§3, Eq. 7 psum restricted to this axis)
    # composed with per-layer inner parallelism on `model` (§4 via
    # core.planner).  K devices per computing node.
    "nodes2xmodel2": ((2, 2), ("nodes", "model")),
    "nodes4xmodel2": ((4, 2), ("nodes", "model")),
    "nodes2xmodel4": ((2, 4), ("nodes", "model")),
    "nodes8xmodel2": ((8, 2), ("nodes", "model")),
}


def make_mesh(name: str, devices=None):
    shape, axes = MESHES[name]
    n = math.prod(shape)
    pool = list(jax.devices() if devices is None else devices)
    if len(pool) < n:
        raise RuntimeError(
            f"mesh {name} needs {n} devices, have {len(pool)} "
            "(the dry-run must set --xla_force_host_platform_device_count "
            "before any jax import)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(pool[:n]).reshape(shape), axes)


def make_nodes_mesh(num_nodes: int, devices=None):
    """1-D ``nodes`` mesh for the device-sharded outer layer.

    One device per computing node, any node count — the named ``nodes<m>``
    MESHES entries are the documented members of the family; this builds
    the same shape for arbitrary m.  Raises RuntimeError when the backend
    has fewer than ``num_nodes`` devices (callers fall back to the
    vmapped single-device emulation).
    """
    if num_nodes < 1:
        raise ValueError("need at least one node")
    pool = list(jax.devices() if devices is None else devices)
    if len(pool) < num_nodes:
        raise RuntimeError(
            f"nodes mesh needs {num_nodes} devices, have {len(pool)} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count to "
            "emulate a multi-device host)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(pool[:num_nodes]), ("nodes",))


def make_hybrid_mesh(num_nodes: int, model_parallel: int, devices=None):
    """2-D ``(nodes, model)`` hybrid mesh for arbitrary axis sizes.

    The ``nodesNxmodelK`` MESHES entries are the documented members of
    the family; this builds the same shape for any ``(N, K)``.  Each of
    the paper's m computing nodes owns ``model_parallel`` devices for
    the planner-driven inner layer.  Raises RuntimeError when the
    backend pool is too small (callers fall back like ``make_nodes_mesh``).
    """
    if num_nodes < 1 or model_parallel < 1:
        raise ValueError("need at least one node and one model shard")
    need = num_nodes * model_parallel
    pool = list(jax.devices() if devices is None else devices)
    if len(pool) < need:
        raise RuntimeError(
            f"hybrid mesh needs {need} devices "
            f"({num_nodes} nodes x {model_parallel} model), have "
            f"{len(pool)} (set XLA_FLAGS="
            "--xla_force_host_platform_device_count to emulate)")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(pool[:need]).reshape(num_nodes, model_parallel),
        ("nodes", "model"))


def make_production_mesh(*, multi_pod: bool = False):
    return make_mesh("multipod" if multi_pod else "pod")


def data_axes(mesh) -> tuple:
    """Mesh axes that carry the batch (outer-layer) dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
