"""Mesh construction for single-pod and multi-pod deployments.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  The dry-run gives jax 512 placeholder host devices; real
deployments get the same shapes from actual TPU topologies.
"""
from __future__ import annotations

import math

import jax

__all__ = ["make_production_mesh", "make_mesh", "data_axes", "MESHES"]

MESHES = {
    "pod": ((16, 16), ("data", "model")),               # 256 chips (v5e pod)
    "multipod": ((2, 16, 16), ("pod", "data", "model")),  # 512 chips
    # reduced meshes for in-test dry-runs (subprocess with 8/16 devices)
    "tiny": ((2, 2), ("data", "model")),
    "tiny3d": ((2, 2, 2), ("pod", "data", "model")),
}


def make_mesh(name: str):
    shape, axes = MESHES[name]
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {name} needs {n} devices, have {len(jax.devices())} "
            "(the dry-run must set --xla_force_host_platform_device_count "
            "before any jax import)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    return make_mesh("multipod" if multi_pod else "pod")


def data_axes(mesh) -> tuple:
    """Mesh axes that carry the batch (outer-layer) dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
