"""Step functions (train / prefill / decode) + abstract input specs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation — so ``jit(...).lower()``
can compile production shapes on placeholder devices.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.types import ModelConfig, ShapeConfig
from repro.models import encdec, lm
from repro.optim.optimizers import (apply_updates, clip_by_global_norm,
                                    make_optimizer)

__all__ = ["input_specs", "abstract_params", "abstract_opt_state",
           "abstract_cache", "make_train_step", "make_prefill_step",
           "make_decode_step", "enc_len", "text_len"]

F32 = jnp.float32
BF16 = jnp.bfloat16
I32 = jnp.int32


def enc_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Encoder length for enc-dec archs: half the shape budget."""
    return shape.seq_len // 2


def text_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Decoder/text token count so total processed length == seq_len."""
    if cfg.arch_type == "encdec":
        return shape.seq_len - enc_len(cfg, shape)
    if cfg.frontend:
        return shape.seq_len - cfg.num_frontend_tokens
    return shape.seq_len


# ----------------------------------------------------------------------
# Abstract inputs
# ----------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract host batch for the given shape preset."""
    B = shape.global_batch
    sds = jax.ShapeDtypeStruct
    if shape.mode in ("train", "prefill"):
        T = text_len(cfg, shape)
        batch = {"tokens": sds((B, T), I32)}
        if shape.mode == "train":
            batch["labels"] = sds((B, T), I32)
        if cfg.arch_type == "encdec":
            batch["frontend_embeds"] = sds((B, enc_len(cfg, shape),
                                            cfg.d_model), BF16)
        elif cfg.frontend:
            batch["frontend_embeds"] = sds((B, cfg.num_frontend_tokens,
                                            cfg.d_model), BF16)
        return batch
    # decode: one token against a seq_len cache
    return {"tokens": sds((B, 1), I32)}


def abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    if cfg.arch_type == "encdec":
        return jax.eval_shape(lambda k: encdec.init_encdec_params(k, cfg), key)
    return jax.eval_shape(lambda k: lm.init_params(k, cfg), key)


def abstract_opt_state(cfg: ModelConfig, optimizer: str = "adamw"):
    opt = make_optimizer(optimizer)
    params = abstract_params(cfg)
    return jax.eval_shape(opt.init, params)


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    B = shape.global_batch
    if cfg.arch_type == "encdec":
        return jax.eval_shape(
            lambda: encdec.init_encdec_cache(
                cfg, B, shape.seq_len, enc_len(cfg, shape)))
    return jax.eval_shape(lambda: lm.init_cache(B, shape.seq_len, cfg))


# ----------------------------------------------------------------------
# Steps
# ----------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, optimizer: str = "adamw",
                    learning_rate: float = 3e-4, grad_clip: float = 1.0,
                    remat: bool = True, scan_unroll: bool = False):
    opt = make_optimizer(optimizer)
    if cfg.arch_type == "encdec":
        def loss(params, batch):
            return encdec.encdec_loss_fn(params, batch, cfg,
                                         scan_unroll=scan_unroll)
    else:
        def loss(params, batch):
            return lm.loss_fn(params, batch, cfg, remat=remat,
                              scan_unroll=scan_unroll)

    def train_step(params, opt_state, batch):
        (lval, aux), grads = jax.value_and_grad(loss, has_aux=True)(params, batch)
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = jnp.zeros(())
        updates, opt_state = opt.update(grads, opt_state, params,
                                        learning_rate)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": lval, "grad_norm": gnorm}
    return train_step


def make_prefill_step(cfg: ModelConfig, scan_unroll: bool = False):
    if cfg.arch_type == "encdec":
        def prefill(params, batch):
            memory = encdec.encode(params, batch["frontend_embeds"], cfg,
                                   scan_unroll=scan_unroll)
            hidden = encdec._decode_stack(
                params, encdec.embed_tokens(params, batch["tokens"], cfg),
                memory, cfg, scan_unroll=scan_unroll)
            return hidden[:, -1]
        return prefill

    def prefill(params, batch):
        hidden, caches, _ = lm.forward(
            params, batch["tokens"], cfg,
            frontend_embeds=batch.get("frontend_embeds"),
            collect_cache=True, scan_unroll=scan_unroll)
        return hidden[:, -1], caches
    return prefill


def make_decode_step(cfg: ModelConfig, scan_unroll: bool = False):
    if cfg.arch_type == "encdec":
        def decode(params, cache, cache_len, batch):
            return encdec.encdec_decode_step(params, cache, cache_len,
                                             batch["tokens"], cfg,
                                             scan_unroll=scan_unroll)
        return decode

    def decode(params, cache, cache_len, batch):
        return lm.decode_step(params, cache, cache_len, batch["tokens"], cfg,
                              scan_unroll=scan_unroll)
    return decode
