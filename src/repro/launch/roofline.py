"""Roofline terms from a compiled dry-run artifact.

    compute term    = HLO_FLOPs        / (chips * peak_FLOP/s)
    memory term     = HLO_bytes        / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis`` supplies FLOPs/bytes; collective bytes are parsed from the
optimized (post-SPMD) HLO text: we sum the *output* operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (3 links/chip assumed shared; we charge the per-link figure).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

__all__ = ["HW", "RooflineReport", "analyze_compiled", "collective_bytes",
           "parse_hlo_collectives", "model_flops"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # bytes/s / chip
    ici_bw: float = 50e9              # bytes/s / link


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_hlo_collectives(hlo_text: str) -> dict:
    """Sum output bytes per collective kind from optimized HLO text."""
    out: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # async pair: count the -start only
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    out["_counts"] = counts
    return out


def collective_bytes(hlo_text: str) -> int:
    d = parse_hlo_collectives(hlo_text)
    return sum(v for k, v in d.items() if not k.startswith("_"))


def model_flops(cfg, shape, text_tokens: Optional[int] = None) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE); D = tokens processed.

    enc-dec: encoder params see encoder tokens, decoder params decoder
    tokens (cross-attention keys priced with the decoder side).
    """
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[shape.mode]
    if cfg.arch_type == "encdec":
        d, L = cfg.d_model, cfg.num_layers
        per_enc = 2 * d * cfg.attn_dim + 2 * d * cfg.kv_dim + 3 * d * cfg.d_ff
        per_dec = 2 * (2 * d * cfg.attn_dim + 2 * d * cfg.kv_dim)             + 3 * d * cfg.d_ff
        n_enc = cfg.num_encoder_layers * per_enc
        n_dec = L * per_dec + cfg.vocab_size * d
        se = shape.seq_len // 2
        sd = shape.seq_len - se
        if shape.mode == "decode":
            return mult * n_dec * shape.global_batch
        return mult * shape.global_batch * (n_enc * se + n_dec * sd)
    if shape.mode == "decode":
        tokens = shape.global_batch     # one token per sequence
    else:
        tokens = shape.global_batch * shape.seq_len
    n = cfg.active_param_count()
    return mult * n * tokens


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_detail: dict
    model_flops_: float
    per_device_hbm: float              # peak memory per device (bytes)

    def terms(self, hw: HW | None = None) -> dict:
        hw = hw or HW()
        t_c = self.hlo_flops / (self.chips * hw.peak_flops)
        t_m = self.hlo_bytes / (self.chips * hw.hbm_bw)
        t_x = self.coll_bytes / (self.chips * hw.ici_bw)
        dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
                  key=lambda kv: kv[1])
        return {
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "bottleneck": dom[0], "bound_s": dom[1],
            "useful_flop_frac": (self.model_flops_ / self.hlo_flops
                                 if self.hlo_flops else 0.0),
        }

    def row(self) -> dict:
        t = self.terms()
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_T": round(self.hlo_flops / 1e12, 2),
            "bytes_G": round(self.hlo_bytes / 1e9, 2),
            "coll_G": round(self.coll_bytes / 1e9, 3),
            "compute_ms": round(t["compute_s"] * 1e3, 3),
            "memory_ms": round(t["memory_s"] * 1e3, 3),
            "collective_ms": round(t["collective_s"] * 1e3, 3),
            "bottleneck": t["bottleneck"],
            "useful_frac": round(t["useful_flop_frac"], 3),
            "hbm_per_dev_GB": round(self.per_device_hbm / 2**30, 3),
        }


def analyze_compiled(compiled, lowered_text: Optional[str], arch: str,
                     shape_name: str, mesh_name: str, chips: int,
                     cfg=None, shape=None) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = lowered_text if lowered_text is not None else compiled.as_text()
    coll = parse_hlo_collectives(text)
    cbytes = sum(v for k, v in coll.items() if not k.startswith("_"))
    mem = compiled.memory_analysis()
    per_dev = 0.0
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes"):
        per_dev += float(getattr(mem, attr, 0.0) or 0.0)
    # arguments+outputs alias (donation); temp is the honest peak extra
    mf = model_flops(cfg, shape) if cfg is not None and shape is not None \
        else 0.0
    return RooflineReport(arch, shape_name, mesh_name, chips, flops, byts,
                          cbytes, coll, mf, per_dev)
