"""Per-architecture parameter/activation/cache sharding rules.

Parameters are matched by pytree path suffix; every rule degrades to
replication when the tensor dim is not divisible by the mesh axis (so the
same rules serve the 16-wide model axis and the tiny test meshes).

Conventions (leading layer axis from the scan stack is never sharded):
  * attention qkv in-proj  : columns on `model`   (head sharding)
  * attention out-proj     : rows on `model`
  * MLP wi/wg              : columns on `model`
  * MLP wo                 : rows on `model`
  * MoE experts            : expert axis on `model` (expert parallelism)
  * embeddings / lm head   : vocab on `model`
  * mamba mixer            : replicated (see DESIGN.md: fused in-proj layout
    boundaries don't align with a 16-way split; hillclimb candidate)
  * norms / scalars        : replicated

These static suffix rules serve the transformer/LLM stacks.  For the
paper's CNN on the 2-D ``(nodes, model)`` hybrid mesh, the per-layer
parallelization is planned by ``core.planner`` instead — a cost-model
search over {batch, channel, replicate} per layer that emits the specs
AND the kernel tiles the round executes (plan == execution).
"""
from __future__ import annotations


import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import data_axes

__all__ = ["param_specs", "param_shardings", "batch_specs", "cache_specs",
           "logical_rules", "opt_state_specs"]


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _maybe(mesh, dim_size: int, axis):
    """Use `axis` if it divides dim_size, else replicate that dim."""
    return axis if dim_size % _axis_size(mesh, axis) == 0 else None


# ----------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------
def _spec_for_path(path: tuple, leaf, mesh) -> P:
    keys = [k.key for k in path if hasattr(k, "key")]
    name = "/".join(keys)
    shape = leaf.shape
    tp = "model"

    def col(idx_from_end=1):
        """Shard the given dim (from the end) on `model` if divisible."""
        ax = [None] * len(shape)
        dim = len(shape) - idx_from_end
        ax[dim] = _maybe(mesh, shape[dim], tp)
        return P(*ax)

    # embeddings & heads: vocab on model (first dim after optional stack)
    if name.endswith(("embed/table", "lm_head/table")):
        return P(_maybe(mesh, shape[0], tp), None)

    # attention projections
    if any(name.endswith(s) for s in ("wq/w", "wk/w", "wv/w")):
        return col(1)
    if "attn" in name and name.endswith("wo/w"):
        return col(2)
    if any(s in name for s in ("self_attn", "cross_attn")) and \
            name.endswith("wo/w"):
        return col(2)

    # MLP
    if any(name.endswith(s) for s in ("wi/w", "wg/w")) and "moe" not in name:
        return col(1)
    if name.endswith("mlp/wo/w"):
        return col(2)

    # MoE: experts on model (expert parallelism); router replicated.
    # Fallback when E doesn't divide the axis (granite: 40 vs 16): shard
    # the per-expert FFN dim instead (expert tensor parallelism) so the
    # expert compute still splits 16 ways (§Perf bonus hc4).
    if "moe" in name and keys[-1] in ("wi", "wg", "wo"):
        ax = [None] * len(shape)
        edim = len(shape) - 3          # (L, E, d, f) or (E, d, f)
        if shape[edim] % _axis_size(mesh, tp) == 0:
            ax[edim] = tp
        else:
            fdim = len(shape) - 1 if keys[-1] in ("wi", "wg") \
                else len(shape) - 2
            ax[fdim] = _maybe(mesh, shape[fdim], tp)
        return P(*ax)

    # frontend projector
    if name.endswith("frontend_proj/w"):
        return col(1)

    # everything else (norms, mamba mixer, biases, scalars): replicated
    return P(*([None] * len(shape)))


def param_specs(params, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_path(path, leaf, mesh), params)


def param_shardings(params, mesh):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec), param_specs(params, mesh))


def opt_state_specs(opt_state, params, mesh):
    """AdamW moments share the param layout; counters are replicated."""
    pspecs = param_specs(params, mesh)

    def match(st):
        if isinstance(st, dict) and "mu" in st:
            return {"mu": pspecs, "nu": pspecs, "count": P()}
        if st == () or st is None:
            return st
        return jax.tree_util.tree_map(lambda _: P(), st)
    return match(opt_state)


# ----------------------------------------------------------------------
# Activations / logical rules
# ----------------------------------------------------------------------
def logical_rules(mesh, cfg=None) -> dict:
    dp = data_axes(mesh)
    tp = mesh.shape["model"]
    heads_ok = cfg is not None and cfg.num_heads and cfg.num_heads % tp == 0
    kv_ok = cfg is not None and cfg.num_kv_heads and cfg.num_kv_heads % tp == 0
    exp_ok = cfg is not None and cfg.num_experts and cfg.num_experts % tp == 0
    ff_ok = cfg is not None and cfg.d_ff and cfg.d_ff % tp == 0
    return {
        "batch": dp if dp else None,
        "seq": "model",       # sequence sharding at layer boundaries (SP)
        "embed": None,
        "vocab": "model",
        # attention computed head-sharded (SP<->TP all-to-all at the block
        # boundary); kv heads replicate when GQA kv < |model|
        "heads": "model" if heads_ok else None,
        "kv_heads": "model" if kv_ok else None,
        "expert": "model" if exp_ok else None,
        # capacity-dim fallback sharding when experts can't split
        "capacity": None if exp_ok else "model",
        "mlp_ff": "model" if ff_ok else None,
        "kv_seq": "model",
        "tp": "model",
        "_axis_sizes": dict(mesh.shape),
    }


# ----------------------------------------------------------------------
# Inputs & caches
# ----------------------------------------------------------------------
def batch_specs(batch_shape_tree, mesh, mode: str):
    """Specs for the host batch: shard batch dim over (pod, data)."""
    dp = data_axes(mesh)

    def spec(leaf):
        b = leaf.shape[0]
        bt = _maybe(mesh, b, dp)
        return P(bt, *([None] * (len(leaf.shape) - 1)))
    return jax.tree_util.tree_map(spec, batch_shape_tree)


def cache_specs(cache_tree, mesh, batch: int):
    """Decode caches (stacked over layers, leading L axis).

    kv k/v: (L, B, S, KH, D) — batch over (pod,data) when divisible, else
    the *sequence* is context-sharded over every available axis (long_500k,
    batch=1).  SSM state: (L, B, H, P, N) — batch over dp, heads on model.
    """
    dp = data_axes(mesh)
    batch_ok = batch % _axis_size(mesh, dp) == 0

    def spec(path, leaf):
        keys = [k.key for k in path if hasattr(k, "key")]
        name = "/".join(keys)
        shp = leaf.shape
        if keys and keys[-1] in ("k", "v") or "cross" in name:
            # (L, B, S, KH, D)
            if batch_ok:
                kh = _maybe(mesh, shp[3], "model")
                seq = "model" if kh is None else None
                seq = _maybe(mesh, shp[2], seq) if seq else None
                return P(None, dp, seq, kh, None)
            all_axes = tuple(mesh.axis_names)
            return P(None, None, _maybe(mesh, shp[2], all_axes), None, None)
        if keys and keys[-1] == "ssm":
            # (L, B, H, P, N)
            bt = dp if batch_ok else None
            return P(None, bt, _maybe(mesh, shp[2], "model"), None, None)
        if keys and keys[-1] == "conv":
            bt = dp if batch_ok else None
            return P(None, bt, None, _maybe(mesh, shp[3], "model"))
        bt = dp if batch_ok else None
        return P(bt, *([None] * (len(shp) - 1)))
    return jax.tree_util.tree_map_with_path(spec, cache_tree)
