"""Serving CLI: thin front-end over ``repro.serving``.

Replays a Poisson request stream through the resolved serve engine and
prints per-request latency plus aggregate throughput — the CPU-scale
twin of the decode path the dry-run lowers at production shapes:

    python -m repro.launch.serve --arch mamba2-370m \
        --requests 16 --rate 50 --slots 4

(``pip install -e .`` first; bare checkouts can prefix ``PYTHONPATH=src``.)
"""
from __future__ import annotations

import argparse
import warnings

import jax
import numpy as np

from repro import configs
from repro.launch import runtime
from repro.serving import ServeConfig, make_serve_engine, poisson_requests


def greedy_generate(params, cfg, prompts, max_seq: int, gen: int):
    """DEPRECATED shim over ``ServeEngine.generate`` — same contract as
    the old token-by-token loop: prompts (B, P) int32 → (B, gen) ids."""
    warnings.warn(
        "launch.serve.greedy_generate is deprecated; use "
        "repro.serving.make_serve_engine(...).generate(prompts, gen)",
        DeprecationWarning, stacklevel=2)
    B = prompts.shape[0]
    eng = make_serve_engine(params, cfg, ServeConfig(
        slots=B, max_seq=max_seq, max_new_tokens=gen))
    return eng.generate(prompts, gen)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson arrival rate (requests/sec)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batching", default="continuous",
                    choices=["continuous", "static"])
    ap.add_argument("--timing", default="measured",
                    choices=["measured", "model"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # persistent XLA cache (default on): repeat serve runs skip the
    # prefill/decode compiles entirely (REPRO_COMPILATION_CACHE=off opts out)
    cache_dir = runtime.maybe_enable_compilation_cache()
    if cache_dir:
        print(f"[serve] compilation cache: {cache_dir}")

    cfg = configs.get_reduced(args.arch)
    # params and prompt stream draw from SPLIT keys (the old demo reused
    # one key for both, correlating weights with the prompt ids)
    key_params, key_prompts = jax.random.split(jax.random.PRNGKey(args.seed))
    from repro.models import lm
    params = lm.init_params(key_params, cfg)
    prompt_seed = int(jax.random.randint(key_prompts, (), 0, 2**31 - 1))

    eng = make_serve_engine(params, cfg, ServeConfig(
        slots=args.slots, max_seq=args.max_seq, max_new_tokens=args.gen,
        batching=args.batching, timing=args.timing))
    reqs = poisson_requests(args.requests, args.rate, seed=prompt_seed,
                            vocab_size=cfg.vocab_size)

    lat, toks = {}, 0
    for ev in eng.run(reqs):
        if ev.kind == "prefill":
            print(f"[serve] req {ev.request:3d} slot {ev.slot} "
                  f"prefill {ev.prefill_ms:7.2f} ms  ttft {ev.ttft_ms:7.2f} ms")
        elif ev.kind == "complete":
            lat[ev.request] = ev.latency_ms
            toks += len(ev.tokens)
            print(f"[serve] req {ev.request:3d} done  t={ev.t_ms:8.1f} ms  "
                  f"latency {ev.latency_ms:7.1f} ms  "
                  f"tokens {np.asarray(ev.tokens)[:8]}...")
            makespan = ev.t_ms
    ls = np.asarray(sorted(lat.values()))
    print(f"[serve] {cfg.name} {eng.batching}: {len(lat)} requests, "
          f"{toks} tokens in {makespan:.1f} ms "
          f"({toks / makespan * 1e3:.1f} tok/s) | latency "
          f"p50 {np.percentile(ls, 50):.1f} ms "
          f"p99 {np.percentile(ls, 99):.1f} ms")
    assert len(lat) == args.requests
    return lat


if __name__ == "__main__":
    main()
