"""Serving driver: batched prefill + decode with a KV/SSM cache.

CPU-scale demo of the decode path the dry-run lowers at production shapes:

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm


def greedy_generate(params, cfg, prompts, max_seq: int, gen: int):
    """prompts: (B, P) int32.  Prefill token-by-token, then greedy decode."""
    B, P = prompts.shape
    cache = lm.init_cache(cfg, B, max_seq)
    step = jax.jit(lambda p, c, n, t: lm.decode_step(p, c, n, t, cfg))
    # prefill via the decode path (exercises cache writes at every pos)
    logits = None
    for i in range(P):
        logits, cache = step(params, cache, jnp.int32(i), prompts[:, i:i + 1])
    out = []
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    for i in range(gen):
        out.append(tok)
        logits, cache = step(params, cache, jnp.int32(P + i), tok)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_reduced(args.arch)
    if cfg.arch_type == "encdec":
        raise SystemExit("decoder-only serving demo; pick another arch")
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    max_seq = args.prompt_len + args.gen + 1
    t0 = time.time()
    out = greedy_generate(params, cfg, prompts, max_seq, args.gen)
    jax.block_until_ready(out)
    wall = time.time() - t0
    total_steps = args.batch * (args.prompt_len + args.gen)
    print(f"[serve] {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen} -> {out.shape} in {wall:.2f}s "
          f"({total_steps / wall:.1f} tok/s incl. compile)")
    print("[serve] generated ids[0]:", np.asarray(out[0]))
    assert not bool(jnp.isnan(out).any())
    return out


if __name__ == "__main__":
    main()
