import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init, and the production meshes need 512
placeholder host devices.  Never import this module from tests/benches that
expect a single device; run it as ``python -m repro.launch.dryrun``.

For each combination this script:
  1. builds the mesh (16x16 pod / 2x16x16 multipod),
  2. lowers the step with explicit in/out shardings over abstract inputs,
  3. compiles the production artifact (scan-over-layers) — proves sharding
     coherence + gives memory_analysis,
  4. compiles two small CALIBRATION artifacts (1 and 2 layers, scans
     unrolled, inner chunk loops widened to one iteration) whose
     cost_analysis counts every op exactly; per-layer deltas are
     extrapolated to the full depth.  This sidesteps XLA's HLO cost
     analysis counting while-loop bodies once (measured, see
     EXPERIMENTS.md §Roofline methodology),
  5. records everything into experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core import shardlib
from repro.launch import roofline, sharding, steps
from repro.launch.mesh import make_mesh

OUT_DIR = "experiments/dryrun"


def _named(tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def build_lowered(cfg, shape, mesh, remat=True, scan_unroll=False,
                  donate=True):
    """Lower one step with explicit shardings; returns jax Lowered."""
    params = steps.abstract_params(cfg)
    pspecs = sharding.param_specs(params, mesh)
    batch = steps.input_specs(cfg, shape)
    bspecs = sharding.batch_specs(batch, mesh, shape.mode)

    with shardlib.rules_scope(sharding.logical_rules(mesh, cfg)):
        if shape.mode == "train":
            opt_state = steps.abstract_opt_state(cfg)
            ospecs = sharding.opt_state_specs(opt_state, params, mesh)
            fn = steps.make_train_step(cfg, remat=remat,
                                       scan_unroll=scan_unroll)
            jitted = jax.jit(
                fn,
                in_shardings=(_named(pspecs, mesh), _named(ospecs, mesh),
                              _named(bspecs, mesh)),
                out_shardings=(_named(pspecs, mesh), _named(ospecs, mesh),
                               NamedSharding(mesh, P())),
                donate_argnums=(0, 1) if donate else ())
            with mesh:
                return jitted.lower(params, opt_state, batch)
        if shape.mode == "prefill":
            fn = steps.make_prefill_step(cfg, scan_unroll=scan_unroll)
            jitted = jax.jit(
                fn, in_shardings=(_named(pspecs, mesh), _named(bspecs, mesh)))
            with mesh:
                return jitted.lower(params, batch)
        # decode
        cache = steps.abstract_cache(cfg, shape)
        cspecs = sharding.cache_specs(cache, mesh, shape.global_batch)
        fn = steps.make_decode_step(cfg, scan_unroll=scan_unroll)
        jitted = jax.jit(
            fn,
            in_shardings=(_named(pspecs, mesh), _named(cspecs, mesh),
                          NamedSharding(mesh, P()), _named(bspecs, mesh)),
            out_shardings=(NamedSharding(mesh, P()), _named(cspecs, mesh)),
            donate_argnums=(1,) if donate else ())
        cache_len = jax.ShapeDtypeStruct((), jnp.int32)
        with mesh:
            return jitted.lower(params, cache, cache_len, batch)


def _costs(compiled, chips):
    """(global_flops, global_bytes, global_coll_bytes, coll_detail)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) * chips      # cost is per-device
    byts = float(cost.get("bytes accessed", 0.0)) * chips
    coll = roofline.parse_hlo_collectives(compiled.as_text())
    cbytes = sum(v for k, v in coll.items() if not k.startswith("_")) * chips
    counts = coll.get("_counts", {})
    return flops, byts, cbytes, counts


def _calib_cfg(cfg, shape, k: int):
    """k-layer calibration config with inner chunk loops widened away."""
    big = max(shape.seq_len, 1)
    kw = dict(num_layers=k, attn_q_chunk=big, attn_k_chunk=big, ce_chunk=big)
    if cfg.num_encoder_layers:
        kw["num_encoder_layers"] = k
    return dataclasses.replace(cfg, **kw)


def calibrated_costs(cfg, shape, mesh, remat=True):
    """Exact-op cost via 1-/2-layer unrolled compiles, extrapolated to L."""
    per = {}
    for k in (1, 2):
        low = build_lowered(_calib_cfg(cfg, shape, k), shape, mesh,
                            remat=remat, scan_unroll=True, donate=False)
        per[k] = _costs(low.compile(), mesh.size)
    L = cfg.num_layers
    df = per[2][0] - per[1][0]
    db = per[2][1] - per[1][1]
    dc = per[2][2] - per[1][2]
    return {
        "flops": per[1][0] + (L - 1) * df,
        "bytes": per[1][1] + (L - 1) * db,
        "coll_bytes": per[1][2] + (L - 1) * dc,
        "per_layer": {"flops": df, "bytes": db, "coll_bytes": dc},
        "outside": {"flops": per[1][0] - df, "bytes": per[1][1] - db,
                    "coll_bytes": per[1][2] - dc},
        "coll_counts_L1": per[1][3],
        "coll_counts_L2": per[2][3],
    }


# Empirically calibrated on this XLA build (see EXPERIMENTS.md §Roofline
# methodology): (bytes_naive - bytes_chunked) / (appearances * B * H * S^2)
# for a 1-layer step.  train (fwd+remat+bwd) = 54.95, prefill (fwd) = 35.02
# B/elem (s f32 w+r, mask/where chain, softmax, p cast + matmul reads).
SCORE_BYTES_PER_ELEM = {"train": 55.0, "prefill": 35.0}


def _attn_score_bytes(cfg, shape) -> float:
    """Analytic traffic of the materialised score/prob matrices the
    calibration's non-chunked attention adds vs the deployed flash path,
    per appearance (train: fwd + remat-recompute + bwd = 3; prefill: 1;
    decode: 0)."""
    if not cfg.num_heads or shape.mode == "decode":
        return 0.0
    if cfg.arch_type == "encdec":
        se = shape.seq_len // 2
        sd = shape.seq_len - se
        elems = cfg.num_encoder_layers * se * se + \
            cfg.num_layers * (sd * sd + sd * se)
    else:
        s = shape.seq_len
        elems = cfg.num_layers * s * s
    appearances = 3 if shape.mode == "train" else 1
    factor = SCORE_BYTES_PER_ELEM[shape.mode]
    return float(appearances * factor * shape.global_batch
                 * cfg.num_heads * elems)


def _banded_flops_corr(cfg, shape) -> float:
    """Analytic FLOP reduction from attn_block_skip: masked-out kv blocks
    (outside the causal/sliding-window band) are lax.cond-skipped at
    runtime, but both the calibration and plain cost analysis price the
    full S^2.  Per windowed layer the live fraction is
    ~(window + q_chunk + k_chunk)/S; causal-global layers ~0.5."""
    if not (cfg.attn_block_skip and cfg.num_heads) or shape.mode == "decode":
        return 0.0
    import numpy as np
    from repro.models.blocks import GLOBAL_WINDOW, layer_windows
    S = shape.seq_len if cfg.arch_type != "encdec" else shape.seq_len // 2
    qc, kc = cfg.attn_q_chunk or 512, cfg.attn_k_chunk or 1024
    wins = np.asarray(layer_windows(cfg))
    fracs = np.where(wins >= GLOBAL_WINDOW, 0.5 + qc / (2 * S),
                     np.minimum(1.0, (wins + qc + kc) / S))
    apps = 3 if shape.mode == "train" else 1
    per_layer_attn = apps * 4.0 * shape.global_batch * cfg.num_heads \
        * S * S * cfg.head_dim
    return float(per_layer_attn * np.sum(1.0 - fracs))


def lower_and_compile(arch: str, shape_name: str, mesh_name: str,
                      variant: str = "", remat: bool = True,
                      verbose: bool = True, calibrate: bool = True,
                      cfg_override=None):
    cfg = cfg_override or configs.get_config(arch, variant)
    shape = configs.get_shape(shape_name)
    mesh = make_mesh(mesh_name)
    chips = mesh.size

    t0 = time.time()
    lowered = build_lowered(cfg, shape, mesh, remat=remat)
    compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    mem_d = {a: float(getattr(mem, a, 0) or 0)
             for a in ("temp_size_in_bytes", "argument_size_in_bytes",
                       "output_size_in_bytes", "generated_code_size_in_bytes")}
    full_flops, full_bytes, full_coll, full_counts = _costs(compiled, chips)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "chips": chips, "compile_s": round(compile_s, 1),
        "memory_analysis": mem_d,
        "full_artifact": {
            "flops_body_once": full_flops, "bytes_body_once": full_bytes,
            "coll_bytes_body_once": full_coll, "coll_counts": full_counts,
        },
    }

    if calibrate:
        cal = calibrated_costs(cfg, shape, mesh, remat=remat)
        score_corr = _attn_score_bytes(cfg, shape)
        banded_corr = _banded_flops_corr(cfg, shape)
        cal_flops = max(cal["flops"] - banded_corr, 0.0)
        # flash-adjusted bytes drive the memory term and the bottleneck:
        # the deployed (chunked/Pallas) attention keeps scores in VMEM, so
        # the naive-calibration score traffic is subtracted analytically.
        bytes_flash = max(cal["bytes"] - score_corr, 0.0)
        rep = roofline.RooflineReport(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
            hlo_flops=cal_flops, hlo_bytes=bytes_flash,
            coll_bytes=cal["coll_bytes"], coll_detail=cal["coll_counts_L2"],
            model_flops_=roofline.model_flops(cfg, shape),
            per_device_hbm=mem_d["temp_size_in_bytes"]
            + mem_d["argument_size_in_bytes"])
        result["calibrated"] = cal
        result["attn_score_bytes_corr"] = score_corr
        result["banded_flops_corr"] = banded_corr
        row = rep.row()
        hw = roofline.HW()
        row["memory_naive_ms"] = round(
            cal["bytes"] / (chips * hw.hbm_bw) * 1e3, 3)
        row["memory_flash_ms"] = row["memory_ms"]
        result["roofline"] = row

    if verbose:
        msg = (f"[dryrun] {arch} x {shape_name} x {mesh_name}"
               f"{' (' + variant + ')' if variant else ''}: "
               f"compile {compile_s:.1f}s")
        if calibrate:
            r = result["roofline"]
            msg += (f"  flops {r['flops_T']}T coll {r['coll_G']}GB "
                    f"bottleneck={r['bottleneck']} "
                    f"useful={r['useful_frac']}")
        print(msg)
        print(f"  memory_analysis: {mem_d}")
    return result


def save_result(result: dict, tag: str = "") -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fn = (f"{OUT_DIR}/{result['arch']}__{result['shape']}__"
          f"{result['mesh']}{suffix}.json")
    with open(fn, "w") as f:
        json.dump(result, f, indent=1)
    return fn


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help=f"one of {configs.ARCH_NAMES} or 'all'")
    ap.add_argument("--shape", default="all",
                    help="train_4k|prefill_32k|decode_32k|long_500k|all")
    ap.add_argument("--mesh", default="pod",
                    help="pod|multipod|tiny|tiny3d|both")
    ap.add_argument("--variant", default="",
                    help="'' or 'swa' (sliding-window long-context variant)")
    ap.add_argument("--tag", default="", help="output filename tag")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the 1/2-layer cost calibration compiles")
    ap.add_argument("--include-skips", action="store_true")
    args = ap.parse_args(argv)

    archs = configs.ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(configs.SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            if (arch, shape) in configs.SKIPS and not args.include_skips \
                    and not args.variant:
                print(f"[skip] {arch} x {shape}: "
                      f"{configs.SKIPS[(arch, shape)]}")
                continue
            for mesh in meshes:
                try:
                    res = lower_and_compile(
                        arch, shape, mesh, variant=args.variant,
                        remat=not args.no_remat,
                        calibrate=not args.no_calibrate)
                    fn = save_result(res, tag=args.tag or args.variant)
                    print(f"  -> {fn}")
                except Exception as e:  # noqa: BLE001 — report every combo
                    traceback.print_exc()
                    failures.append((arch, shape, mesh, str(e)[:200]))
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        return 1
    print("\nall dry-runs passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
