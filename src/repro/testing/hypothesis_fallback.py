"""Dependency-free fallback for the slice of the ``hypothesis`` API the
test-suite uses (``given``, ``settings``, ``strategies.integers/floats/
lists/sampled_from``).

Hermetic containers without network access cannot install the real
``hypothesis`` (it is declared in the ``test`` extra, and CI uses it);
``install()`` registers this module under the ``hypothesis`` name so the
property-based tests still *run* offline.  It is a miniature example
generator, not a replacement: no shrinking, no coverage-guided search.
Examples are deterministic — boundary probes (all-min, all-max) first,
then pseudo-random draws seeded from the test's qualified name — so a
failure reproduces across runs.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np

__all__ = ["install", "given", "settings", "strategies"]


class SearchStrategy:
    """Base strategy: ``example(rng)`` draws one value, ``boundary()``
    returns deterministic edge values probed before the random draws."""

    def example(self, rng: np.random.Generator):
        raise NotImplementedError

    def boundary(self) -> list:
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, min_value: int, max_value: int):
        self.min_value, self.max_value = int(min_value), int(max_value)

    def example(self, rng):
        return int(rng.integers(self.min_value, self.max_value + 1))

    def boundary(self):
        return [self.min_value, self.max_value]


class _Floats(SearchStrategy):
    def __init__(self, min_value: float, max_value: float):
        self.min_value, self.max_value = float(min_value), float(max_value)

    def example(self, rng):
        return float(self.min_value
                     + (self.max_value - self.min_value) * rng.random())

    def boundary(self):
        return [self.min_value, self.max_value]


class _SampledFrom(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty sequence")

    def example(self, rng):
        return self.elements[int(rng.integers(len(self.elements)))]

    def boundary(self):
        return [self.elements[0], self.elements[-1]]


class _Lists(SearchStrategy):
    def __init__(self, elements: SearchStrategy, min_size: int = 0,
                 max_size: int | None = None):
        self.elements = elements
        self.min_size = int(min_size)
        self.max_size = self.min_size + 10 if max_size is None else int(max_size)

    def example(self, rng):
        n = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elements.example(rng) for _ in range(n)]

    def boundary(self):
        lo, hi = self.elements.boundary()[0], self.elements.boundary()[-1]
        return [[lo] * self.min_size, [hi] * self.max_size]


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return _Integers(min_value, max_value)


def floats(min_value: float, max_value: float) -> SearchStrategy:
    return _Floats(min_value, max_value)


def sampled_from(elements) -> SearchStrategy:
    return _SampledFrom(elements)


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int | None = None) -> SearchStrategy:
    return _Lists(elements, min_size=min_size, max_size=max_size)


# ----------------------------------------------------------------------
class settings:
    """Decorator recording example-count; deadlines are ignored."""

    def __init__(self, max_examples: int = 50, deadline=None, **_ignored):
        self.max_examples = int(max_examples)
        self.deadline = deadline

    def __call__(self, func):
        func._fallback_settings = self
        return func


_DEFAULT_SETTINGS = settings()


def given(*arg_strategies, **kw_strategies):
    """Run the wrapped test over generated examples.

    Mirrors hypothesis' binding rules: positional strategies map onto the
    *rightmost* parameters of the test function, keyword strategies by name.
    The drawn parameters are stripped from the wrapper's signature so pytest
    does not mistake them for fixtures.
    """
    if arg_strategies and kw_strategies:
        raise TypeError("mix of positional and keyword strategies unsupported")

    def decorate(func):
        sig = inspect.signature(func)
        names = list(sig.parameters)
        if arg_strategies:
            bound = dict(zip(names[len(names) - len(arg_strategies):],
                             arg_strategies, strict=True))
        else:
            bound = dict(kw_strategies)
        missing = set(bound) - set(names)
        if missing:
            raise TypeError(f"strategies for unknown parameters: {missing}")

        @functools.wraps(func)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_fallback_settings", _DEFAULT_SETTINGS)
            rng = np.random.default_rng(
                zlib.crc32(func.__qualname__.encode()))
            for i in range(max(cfg.max_examples, 2)):
                drawn = {}
                for name, strat in bound.items():
                    edges = strat.boundary()
                    if i < 2:               # all-min then all-max probes
                        drawn[name] = edges[0] if i == 0 else edges[-1]
                    else:
                        drawn[name] = strat.example(rng)
                try:
                    func(*args, **drawn, **kwargs)
                except Exception:
                    print(f"Falsifying example ({func.__qualname__}): "
                          f"{drawn!r}", file=sys.stderr)
                    raise

        wrapper.__signature__ = sig.replace(
            parameters=[p for n, p in sig.parameters.items() if n not in bound])
        wrapper.hypothesis = types.SimpleNamespace(inner_test=func)
        return wrapper

    return decorate


# ----------------------------------------------------------------------
strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.floats = floats
strategies.lists = lists
strategies.sampled_from = sampled_from
strategies.SearchStrategy = SearchStrategy


def install():
    """Register this fallback under ``hypothesis`` in ``sys.modules``.

    No-op if the real hypothesis is importable or a fallback is already
    installed.  Returns the module object that will serve ``import
    hypothesis``.
    """
    if "hypothesis" in sys.modules:
        return sys.modules["hypothesis"]
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__is_repro_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
    return mod
