"""Data pipeline: sequence packing, sharded host loading, IDPA partitioning.

``IDPADataset`` glues the paper's partitioner (core/idpa.py) to an actual
dataset: each virtual computing node (data-parallel group) owns the sample
stripe the partitioner assigned it, re-partitioned incrementally as measured
throughputs arrive — the production analogue of Alg. 3.1 where the "main
server" is the input pipeline.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.idpa import IDPAPartitioner, UDPAPartitioner

__all__ = ["pack_sequences", "IDPADataset", "host_batch"]


def pack_sequences(corpus: np.ndarray, seq_len: int) -> np.ndarray:
    """Pack a token stream into (N, seq_len+1) rows (inputs+shifted labels)."""
    if seq_len < 1:
        raise ValueError(f"seq_len must be >= 1, got {seq_len}")
    if len(corpus) < seq_len + 1:
        raise ValueError(
            f"corpus of {len(corpus)} tokens is too short to pack even one "
            f"row: need at least seq_len + 1 = {seq_len + 1} tokens")
    n = (len(corpus) - 1) // seq_len
    rows = np.stack([corpus[i * seq_len:(i + 1) * seq_len + 1]
                     for i in range(n)])
    return rows.astype(np.int32)


def host_batch(rows: np.ndarray):
    """(B, S+1) rows -> {'tokens': (B,S), 'labels': (B,S)}."""
    return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


class IDPADataset:
    """Per-node dataset views driven by the IDPA/UDPA partitioner.

    Usage:
        ds = IDPADataset(data_arrays, num_nodes=4, batches=4,
                         frequencies=[...])
        for epoch_round in range(...):
            views = ds.node_views()          # list of per-node index arrays
            ...train...
            ds.report_durations(durations)   # feeds Alg. 3.1
    """

    def __init__(self, arrays: dict, num_nodes: int, batches: int,
                 frequencies: Optional[Sequence[float]] = None,
                 partitioning: str = "idpa", idpa_mode: str = "paper"):
        self.arrays = arrays
        self.n = len(next(iter(arrays.values())))
        if partitioning == "idpa":
            if frequencies is None:
                frequencies = np.ones(num_nodes)
            self.part = IDPAPartitioner(self.n, num_nodes, batches,
                                        frequencies=frequencies,
                                        mode=idpa_mode)
        else:
            self.part = UDPAPartitioner(self.n, num_nodes, batches)
        self.part.first_batch()

    @property
    def totals(self) -> np.ndarray:
        return self.part.totals

    def report_durations(self, durations, active=None) -> bool:
        """Feed measured per-node durations; returns True if re-allocated.

        ``active`` masks failed nodes out of the next allocation batch
        (node churn): a dead node keeps its existing stripe but receives
        nothing new until it rejoins.
        """
        if self.part.done:
            return False
        if isinstance(self.part, IDPAPartitioner):
            self.part.next_batch(durations, active=active)
        else:
            self.part.next_batch(None, active=active)
        return True

    # -- crash-safe checkpointing: the partitioner's incremental state ---
    def state_dict(self) -> dict:
        return self.part.state_dict()

    def load_state_dict(self, state: dict) -> None:
        self.part.load_state_dict(state)

    def node_views(self) -> list[np.ndarray]:
        """Contiguous index stripes per node (no migration — paper §3.3.1)."""
        totals = self.part.totals
        starts = np.concatenate([[0], np.cumsum(totals)[:-1]])
        return [np.arange(starts[j], starts[j] + totals[j]) % self.n
                for j in range(len(totals))]

    @staticmethod
    def _select(view: np.ndarray, node: int, batch_size: int,
                rng: np.random.Generator) -> np.ndarray:
        """Sample indices from one node's stripe — the ONE sampling rule
        both the sequential and the stacked batch paths share, so their
        numerical equivalence holds by construction."""
        take = min(batch_size, len(view))
        if take == 0:
            raise ValueError(f"node {node} has no samples allocated yet")
        return rng.choice(view, size=batch_size, replace=take < batch_size)

    def node_batch(self, node: int, batch_size: int, rng: np.random.Generator):
        sel = self._select(self.node_views()[node], node, batch_size, rng)
        return {k: v[sel] for k, v in self.arrays.items()}

    @property
    def num_nodes(self) -> int:
        return self.part.num_nodes

    def node_round_batch_sizes(self, batch_size: int) -> np.ndarray:
        """Per-node effective batch sizes ∝ the current IDPA allocation.

        The fastest node (largest stripe) trains on the full
        ``batch_size``; slower nodes get proportionally smaller effective
        loads — the heterogeneity-aware workload the partitioner encodes,
        carried into each round's compute.
        """
        totals = np.maximum(self.totals, 1).astype(np.float64)
        sizes = np.ceil(batch_size * totals / totals.max()).astype(np.int64)
        return np.clip(sizes, 1, batch_size)

    def stacked_round_batches(self, batch_size: int, local_steps: int,
                              rng: np.random.Generator, *,
                              uneven: bool = False):
        """One SGWU round's data for ALL nodes: ``(m, local_steps, B, ...)``.

        Draws node-by-node, step-by-step — the exact RNG consumption
        order of the sequential per-node loop's ``node_batch`` calls — so
        the fused vmapped round sees bit-identical batches and stays
        numerically equivalent to the legacy path on a fixed seed.  The
        index stripes are built once for the round (the allocation only
        changes between rounds, via ``report_durations``).

        With ``uneven=True`` each node draws only its
        ``node_round_batch_sizes`` share and the stripe is padded back to
        ``batch_size`` (cycling the drawn samples) with a float ``mask``
        leaf of shape ``(m, local_steps, B)`` marking the real rows — the
        static-shape realization of IDPA's per-node loads that the
        fused/device-sharded round needs (the loss must honour
        ``batch["mask"]``).
        """
        m = self.num_nodes
        views = self.node_views()
        sizes = self.node_round_batch_sizes(batch_size) if uneven \
            else np.full(m, batch_size, np.int64)
        mask = np.zeros((m, local_steps, batch_size), np.float32)
        sels = []
        for j in range(m):
            node = []
            for s in range(local_steps):
                sel = self._select(views[j], j, int(sizes[j]), rng)
                if len(sel) < batch_size:      # pad by cycling; masked out
                    sel = np.resize(sel, batch_size)
                node.append(sel)
                mask[j, s, :sizes[j]] = 1.0
            sels.append(node)
        out = {k: np.stack([np.stack([v[sel] for sel in node])
                            for node in sels])
               for k, v in self.arrays.items()}
        if uneven:
            out["mask"] = mask
        return out
