"""core.planner: the per-layer parallelization planner for hybrid meshes.

Unit tests run on any device count (plans are mesh-shape functions); the
"scheduled == executed" engine assertions need forced host devices and
skip otherwise (the CI multidevice job runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import planner
from repro.core.bpt_trainer import BPTTrainer
from repro.core.dag import choose_fc_block, choose_oc_tile
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.launch.mesh import make_hybrid_mesh, make_nodes_mesh
from repro.models.cnn import CNNConfig, cnn_loss, init_cnn

NDEV = len(jax.devices())


def need_devices(m):
    return pytest.mark.skipif(
        NDEV < m, reason=f"needs {m} devices (have {NDEV}); run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8")


CFG = CNNConfig(name="plan", image_size=8, conv_layers=1, filters=4,
                fc_layers=2, fc_neurons=32)


class TestPlanForAxes:
    def test_replicate_when_model_is_1(self):
        plan = planner.plan_for_axes(CFG, nodes=4, model=1, batch_size=32)
        assert plan.family == "replicate"
        assert plan.batch_spec == P("nodes")
        assert not plan.combine_grads
        assert all(lp.parallel_dim == "replicate" for lp in plan.layers)

    def test_layer_walk_covers_network(self):
        plan = planner.plan_for_axes(CFG, nodes=4, model=2, batch_size=32)
        names = [lp.name for lp in plan.layers]
        assert names == ["conv0", "pool0", "fc0", "fc1"]
        kinds = [lp.kind for lp in plan.layers]
        assert kinds == ["conv", "pool", "fc", "fc"]

    def test_batch_family_shards_batch(self):
        plan = planner.plan_for_axes(CFG, nodes=4, model=2, batch_size=32,
                                     family="batch")
        assert plan.family == "batch"
        assert plan.combine_grads
        assert plan.batch_spec == P("nodes", None, "model")
        assert all(lp.parallel_dim == "batch" for lp in plan.layers)
        assert all(lp.spec == P("model") for lp in plan.layers)

    def test_batch_tiles_use_local_shapes(self):
        """The executed conv/fc tiles are the Alg. 4.2 choices on the
        POST-sharDING local shapes (B/K rows), not the global ones."""
        plan = planner.plan_for_axes(CFG, nodes=4, model=2, batch_size=32,
                                     family="batch")
        by_name = {lp.name: lp for lp in plan.layers}
        assert by_name["conv0"].tile == choose_oc_tile(16, CFG.filters)
        assert by_name["fc0"].tile == choose_fc_block(CFG.fc_neurons)
        assert by_name["pool0"].tile == 0

    def test_channel_family_tiles_use_local_width(self):
        plan = planner.plan_for_axes(CFG, nodes=2, model=2, batch_size=32,
                                     family="channel")
        assert plan.family == "channel"
        assert not plan.combine_grads
        assert plan.batch_spec == P("nodes")    # batch stays replicated
        by_name = {lp.name: lp for lp in plan.layers}
        # forced channel goes column-parallel wherever the width divides
        assert by_name["fc0"].parallel_dim == "channel"
        assert by_name["fc0"].tile == choose_fc_block(CFG.fc_neurons // 2)
        assert by_name["fc0"].spec == P(None, "model")
        # convs never offer channel (planned-but-not-executed dimension)
        assert by_name["conv0"].parallel_dim == "replicate"

    def test_indivisible_batch_forces_channel_or_raises(self):
        # B=30, K=4: batch family infeasible
        plan = planner.plan_for_axes(CFG, nodes=2, model=4, batch_size=30)
        assert plan.family == "channel"
        with pytest.raises(ValueError, match="infeasible"):
            planner.plan_for_axes(CFG, nodes=2, model=4, batch_size=30,
                                  family="batch")

    def test_generic_plan_without_cfg(self):
        plan = planner.plan_for_axes(None, nodes=4, model=2, batch_size=32)
        assert plan.family == "batch" and plan.layers == ()
        assert plan.combine_grads
        with pytest.raises(ValueError, match="divisible"):
            planner.plan_for_axes(None, nodes=4, model=4, batch_size=30)
        with pytest.raises(ValueError, match="CNNConfig"):
            planner.plan_for_axes(None, nodes=4, model=2, batch_size=32,
                                  family="channel")

    def test_plan_is_hashable(self):
        a = planner.plan_for_axes(CFG, nodes=4, model=2, batch_size=32)
        b = planner.plan_for_axes(CFG, nodes=4, model=2, batch_size=32)
        assert hash(a) == hash(b) and a == b
        assert len({a, b}) == 1

    def test_costs_populate(self):
        plan = planner.plan_for_axes(CFG, nodes=4, model=2, batch_size=32)
        assert plan.total_cost_s > 0
        assert plan.total_cost_s == pytest.approx(
            sum(lp.cost_s for lp in plan.layers))
        for lp in plan.layers:
            assert lp.flops > 0 or lp.kind == "pool"


class TestPlanNetworkOnMesh:
    @need_devices(4)
    def test_mesh_axes_extracted(self):
        mesh = make_hybrid_mesh(2, 2)
        plan = planner.plan_network(CFG, mesh, batch_size=32)
        assert (plan.nodes, plan.model) == (2, 2)

    @need_devices(2)
    def test_1d_mesh_degrades_to_replicate(self):
        plan = planner.plan_network(CFG, make_nodes_mesh(2), batch_size=32)
        assert plan.model == 1 and plan.family == "replicate"


class TestPlanScope:
    def test_take_walks_layers_in_kind_order(self):
        plan = planner.plan_for_axes(CFG, nodes=2, model=2, batch_size=32,
                                     family="batch")
        with planner.plan_scope(plan) as sc:
            assert planner.take("conv").name == "conv0"
            assert planner.take("fc").name == "fc0"
            assert planner.take("fc").name == "fc1"
            # cursor wraps per kind: a second traversal realigns
            assert planner.take("fc").name == "fc0"
            assert planner.take("missing") is None
        assert [lp.name for lp in sc.executed] == \
            ["conv0", "fc0", "fc1", "fc0"]

    def test_no_scope_is_inert(self):
        assert planner.take("conv") is None
        assert planner.current_plan() is None


def _run_sgwu(m, *, device, mesh_name="", family="", uneven=False,
              rounds=3, model_cfg=True):
    cfg = CNNConfig(name="equiv", image_size=8, conv_layers=1, filters=4,
                    fc_layers=1, fc_neurons=32)
    xs, ys = image_dataset(64 * m * 2, size=8, seed=0)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    freqs = np.linspace(1.0, 2.0, m) if uneven else None
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=m, batches=1,
                     frequencies=freqs)
    tc = TrainConfig(outer_strategy="sgwu", outer_nodes=m,
                     optimizer="adamw", learning_rate=2e-3,
                     total_steps=100, warmup_steps=5, local_steps=2,
                     seed=0, device_outer=device, uneven_batches=uneven,
                     mesh_name=mesh_name)
    tr = BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}), params, ds, tc,
                    batch_size=32, model_cfg=cfg if model_cfg else None,
                    plan_family=family)
    return tr.train(rounds=rounds), tr


class TestScheduledEqualsExecuted:
    """The acceptance assertion: the NetworkPlan the planner emits is
    exactly what the 2-D round executes — the kernels consumed the SAME
    LayerPlan objects (tiles included), and the on-device batch sharding
    is the plan's batch_spec."""

    @need_devices(4)
    @pytest.mark.parametrize("family", ["", "channel"])
    def test_engine_executes_the_plan(self, family):
        _, tr = _run_sgwu(2, device=True, mesh_name="nodes2xmodel2",
                          family=family, rounds=2)
        eng = tr.last_engine
        assert eng.netplan is not None
        want = planner.plan_for_axes(
            CNNConfig(name="equiv", image_size=8, conv_layers=1, filters=4,
                      fc_layers=1, fc_neurons=32),
            nodes=2, model=2, batch_size=32, family=family)
        assert eng.netplan == want                  # scheduled
        # executed: the round's kernel dispatches consumed exactly the
        # plan's conv/fc layers, in forward order (pools take no plan)
        planned = [lp for lp in eng.netplan.layers if lp.kind != "pool"]
        assert eng.executed[:len(planned)] == planned
        for got in eng.executed:                    # tiles included
            assert got in planned

    @need_devices(4)
    def test_batch_sharding_is_the_plan_spec(self):
        _, tr = _run_sgwu(2, device=True, mesh_name="nodes2xmodel2",
                          rounds=1)
        eng = tr.last_engine
        assert eng.netplan.family == "batch"
        # the engine's batch placement object carries the plan's spec
        state = eng.setup(1)
        assert state.batch_sharding.spec == eng.netplan.batch_spec

    @need_devices(4)
    def test_generic_plan_without_model_cfg(self):
        rep, tr = _run_sgwu(2, device=True, mesh_name="nodes2xmodel2",
                            rounds=2, model_cfg=False)
        assert tr.last_engine.netplan.family == "batch"
        assert tr.last_engine.netplan.layers == ()
        assert np.isfinite(rep.losses).all()


class TestGradCombine:
    """The batch-family recombiner is EXACT for per-example-mean losses,
    masked or not — checked against the unsharded gradient under a real
    shard_map over a `model` axis."""

    @need_devices(2)
    @pytest.mark.parametrize("masked", [False, True])
    def test_exact_recombination(self, masked):
        from jax.experimental.shard_map import shard_map
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:2]), ("model",))
        plan = planner.plan_for_axes(None, nodes=1, model=2, batch_size=8)
        combine = planner.grad_combine(plan)
        w = jnp.linspace(0.1, 0.5, 5)
        x = jnp.arange(8.0).reshape(8, 1) * jnp.ones((8, 5))
        if masked:
            mask = jnp.array([1, 1, 1, 0, 1, 1, 0, 0], jnp.float32)
        else:
            mask = jnp.ones((8,), jnp.float32)

        def loss_fn(w, batch):
            per = jnp.sum(batch["x"] * w, axis=-1) ** 2
            m = batch["mask"]
            return jnp.sum(per * m) / jnp.maximum(jnp.sum(m), 1.0)

        want_loss, want_grad = jax.value_and_grad(loss_fn)(
            w, {"x": x, "mask": mask})

        def body(w, x, mask):
            batch = {"x": x, "mask": mask}
            loss, grad = jax.value_and_grad(loss_fn)(w, batch)
            loss, grad = combine(loss, (grad,), batch)
            return loss, grad[0]

        got_loss, got_grad = shard_map(
            body, mesh=mesh, in_specs=(P(), P("model"), P("model")),
            out_specs=(P(), P()))(w, x, mask)
        np.testing.assert_allclose(np.asarray(got_loss), want_loss,
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(got_grad), want_grad,
                                   rtol=1e-6)


class TestChannelCollectives:
    """rep_in/shard_dim/gather_cols make the column-parallel fc gradient
    bit-exact against the unsharded layer (the K x trap regression)."""

    @need_devices(2)
    def test_column_parallel_fc_grads_exact(self):
        from jax.experimental.shard_map import shard_map
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:2]), ("model",))
        k = jax.random.PRNGKey(0)
        x = jax.random.normal(k, (4, 6))
        w = jax.random.normal(jax.random.fold_in(k, 1), (6, 8))
        b = jax.random.normal(jax.random.fold_in(k, 2), (8,))

        def ref_loss(w, b):
            return jnp.sum((x @ w + b) ** 2)

        want = jax.value_and_grad(ref_loss, argnums=(0, 1))(w, b)

        def sharded_loss(x, w, b):
            xr = planner.rep_in(x, "model")
            ws = planner.shard_dim(w, 2, 8, "model")
            bs = planner.shard_dim(b, 2, 8, "model")
            y = planner.gather_cols(xr @ ws + bs, 2, "model")
            return jnp.sum(y ** 2)

        got = shard_map(
            jax.value_and_grad(sharded_loss, argnums=(1, 2)),
            mesh=mesh, in_specs=(P(), P(), P()),
            out_specs=(P(), (P(), P())), check_rep=False)(x, w, b)
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                                   rtol=1e-6)
        for g, wg in zip(got[1], want[1], strict=True):
            np.testing.assert_allclose(np.asarray(g), np.asarray(wg),
                                       rtol=1e-6, atol=1e-7)
