"""Property tests for the crash-safe checkpoint layer (repro.checkpointing).

Runs under real ``hypothesis`` when installed, else under the vendored
fallback (``repro.testing.hypothesis_fallback``, installed by conftest) —
the properties draw from integer seed strategies and build pytrees
deterministically from the seed, which both generators support.

Covered contracts:

* save/restore round-trips arbitrary NESTED pytrees — dicts (DictKey),
  lists (SequenceKey ``#i``), registered dataclasses (GetAttrKey) — with
  mixed dtypes (float32 / bfloat16 / int32), bit-exactly;
* ``latest_step`` ignores strays: ``*.tmp`` files, manifests, other
  checkpoint kinds, unrelated names;
* corrupt / truncated / drifted payloads raise ``CheckpointError`` naming
  the offending file — never a bare numpy traceback;
* atomic-write discipline: a save never leaves ``*.tmp`` strays, a kill
  mid-write publishes nothing, and the manifest is published BEFORE the
  payload so a visible ``.npz`` always has its manifest.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpointing import checkpoint
from repro.checkpointing.checkpoint import CheckpointError


@dataclasses.dataclass
class OptSlot:
    """Registered dataclass node: leaves reached via GetAttrKey paths."""
    mu: object
    nu: object
    count: object


jax.tree_util.register_dataclass(
    OptSlot, data_fields=["mu", "nu", "count"], meta_fields=[])

DTYPES = ("float32", "bfloat16", "int32")


def _leaf(rng, dtype):
    shape = tuple(int(s) for s in
                  rng.integers(1, 4, size=int(rng.integers(0, 3))))
    if dtype == "int32":
        return np.asarray(rng.integers(-1000, 1000, size=shape), np.int32)
    a = rng.standard_normal(shape).astype(np.float32) * 8
    if dtype == "bfloat16":
        return jnp.asarray(a, dtype=jnp.bfloat16)
    return a


def make_tree(seed: int):
    """Deterministic nested pytree: dict + list + dataclass structure,
    mixed dtypes, shapes drawn from the seed."""
    rng = np.random.default_rng(seed)
    dt = lambda: DTYPES[int(rng.integers(len(DTYPES)))]      # noqa: E731
    return {
        "params": {
            "dense": [_leaf(rng, dt()) for _ in
                      range(int(rng.integers(1, 4)))],
            "conv": {"w": _leaf(rng, "float32"),
                     "b": _leaf(rng, dt())},
        },
        "opt": OptSlot(mu=_leaf(rng, dt()), nu=_leaf(rng, "bfloat16"),
                       count=np.asarray(int(rng.integers(0, 99)), np.int32)),
    }


def _zeros_like(tree):
    return jax.tree_util.tree_map(lambda x: np.zeros_like(x), tree)


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb, strict=True):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        assert x.shape == y.shape
        np.testing.assert_array_equal(x, y)


# ----------------------------------------------------------------------
class TestRoundTripProperties:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_save_restore_round_trips_bit_exactly(self, seed, tmp_path):
        path = tmp_path / str(seed)      # one dir per drawn example
        tree = make_tree(seed)
        step = seed % 1000
        checkpoint.save(str(path), tree, step=step)
        restored, got = checkpoint.restore(str(path), _zeros_like(tree),
                                           step=step)
        assert got == step
        _assert_trees_equal(tree, restored)
        # atomicity: a completed save leaves no temp strays behind
        assert not [f for f in os.listdir(path) if f.endswith(".tmp")]

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_state_round_trip_preserves_scalars(self, seed, tmp_path):
        tree = make_tree(seed)
        rng = np.random.default_rng(seed)
        scalars = {"clock": float(rng.random() * 100),
                   "heap": [[float(rng.random()), int(j), 0, 0]
                            for j in range(int(rng.integers(1, 5)))],
                   "down": sorted(int(x) for x in
                                  rng.integers(0, 8, size=2)),
                   "nested": {"epoch": [1, 2, 3], "label": "run"}}
        path = tmp_path / str(seed)
        checkpoint.save_state(str(path), tree, seed % 1000, scalars)
        arrays, got_scalars, _ = checkpoint.restore_state(
            str(path), _zeros_like(tree))
        _assert_trees_equal(tree, arrays)
        # scalars survive the JSON round trip verbatim
        assert json.loads(json.dumps(scalars)) == got_scalars

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_latest_step_picks_max_and_ignores_strays(self, seed, tmp_path):
        path = tmp_path / str(seed)
        rng = np.random.default_rng(seed)
        steps = sorted({int(s) for s in rng.integers(0, 5000, size=4)})
        tree = {"w": np.ones(2, np.float32)}
        for s in steps:
            checkpoint.save(str(path), tree, step=s)
        # strays that must all be invisible to latest_step(kind="ckpt")
        (path / "ckpt_99999999.npz.tmp").write_bytes(b"partial")
        (path / "notes.txt").write_text("hi")
        (path / "ckpt_abc.npz").write_bytes(b"junk")
        checkpoint.save(str(path), tree, step=7777, kind="state")
        assert checkpoint.latest_step(str(path)) == steps[-1]
        assert checkpoint.latest_step(str(path), kind="state") == 7777
        restored, got = checkpoint.restore(str(path), _zeros_like(tree))
        assert got == steps[-1]


# ----------------------------------------------------------------------
class TestCorruptionHandling:
    def _saved(self, tmp_path, step=3):
        tree = make_tree(0)
        checkpoint.save(str(tmp_path), tree, step=step)
        return tree, str(tmp_path), \
            tmp_path / checkpoint._payload_name("ckpt", step)

    def test_truncated_payload_raises_checkpoint_error(self, tmp_path):
        tree, path, payload = self._saved(tmp_path)
        raw = payload.read_bytes()
        payload.write_bytes(raw[: len(raw) // 2])
        with pytest.raises(CheckpointError, match="corrupt or was "
                           "truncated"):
            checkpoint.restore(path, _zeros_like(tree))

    def test_garbage_payload_raises_checkpoint_error(self, tmp_path):
        tree, path, payload = self._saved(tmp_path)
        payload.write_bytes(b"\x00" * 128)
        with pytest.raises(CheckpointError, match=str(payload)):
            checkpoint.restore(path, _zeros_like(tree))

    def test_manifest_drift_raises_checkpoint_error(self, tmp_path):
        tree, path, _ = self._saved(tmp_path)
        mpath = tmp_path / "ckpt_00000003.json"
        manifest = json.loads(mpath.read_text())
        key = next(iter(manifest["keys"]))
        manifest["keys"][key]["dtype"] = "float64"
        mpath.write_text(json.dumps(manifest))
        with pytest.raises(CheckpointError, match="drifted"):
            checkpoint.restore(path, _zeros_like(tree))

    def test_manifest_missing_key_raises_checkpoint_error(self, tmp_path):
        tree, path, _ = self._saved(tmp_path)
        mpath = tmp_path / "ckpt_00000003.json"
        manifest = json.loads(mpath.read_text())
        manifest["keys"].pop(next(iter(manifest["keys"])))
        mpath.write_text(json.dumps(manifest))
        with pytest.raises(CheckpointError, match="out of sync"):
            checkpoint.restore(path, _zeros_like(tree))

    def test_corrupt_manifest_raises_checkpoint_error(self, tmp_path):
        tree, path, _ = self._saved(tmp_path)
        (tmp_path / "ckpt_00000003.json").write_text("{not json")
        with pytest.raises(CheckpointError, match="manifest"):
            checkpoint.restore(path, _zeros_like(tree))

    def test_shape_mismatch_raises_checkpoint_error(self, tmp_path):
        path = str(tmp_path)
        checkpoint.save(path, {"w": np.ones((2, 3), np.float32)}, step=1)
        with pytest.raises(CheckpointError, match="shape"):
            checkpoint.restore(path, {"w": np.zeros((4, 4), np.float32)})

    def test_missing_template_keys_raise_key_error(self, tmp_path):
        path = str(tmp_path)
        checkpoint.save(path, {"w": np.ones(2, np.float32)}, step=1)
        with pytest.raises(KeyError, match="missing keys"):
            checkpoint.restore(path, {"w": np.zeros(2, np.float32),
                                      "extra": np.zeros(1, np.float32)})

    def test_empty_dir_raises_file_not_found(self, tmp_path):
        with pytest.raises(FileNotFoundError, match="no checkpoints"):
            checkpoint.restore(str(tmp_path), {"w": np.zeros(1)})

    def test_bad_kind_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="kind"):
            checkpoint.save(str(tmp_path), {"w": np.zeros(1)}, kind="weird")


# ----------------------------------------------------------------------
class TestAtomicity:
    def test_kill_mid_payload_write_publishes_nothing(self, tmp_path):
        """A crash inside the payload write must leave the published name
        absent — only a ``.tmp`` stray, which latest_step ignores and the
        next save overwrites."""
        final = tmp_path / "ckpt_00000001.npz"

        def boom(f):
            f.write(b"half a payload")
            raise OSError("disk gone")

        with pytest.raises(OSError):
            checkpoint._atomic_write_bytes(str(final), boom)
        assert not final.exists()
        assert (tmp_path / "ckpt_00000001.npz.tmp").exists()
        assert checkpoint.latest_step(str(tmp_path)) is None
        # recovery: a clean save at the same step just works
        checkpoint.save(str(tmp_path), {"w": np.ones(1, np.float32)}, step=1)
        assert checkpoint.latest_step(str(tmp_path)) == 1

    def test_manifest_published_before_payload(self, tmp_path, monkeypatch):
        """Kill between manifest and payload: no ``.npz`` becomes visible,
        so latest_step never points at a manifest-only step."""
        def no_savez(*a, **k):
            raise OSError("killed between manifest and payload")

        monkeypatch.setattr(checkpoint.np, "savez", no_savez)
        with pytest.raises(OSError):
            checkpoint.save(str(tmp_path), {"w": np.ones(1, np.float32)},
                            step=5)
        assert (tmp_path / "ckpt_00000005.json").exists()
        assert checkpoint.latest_step(str(tmp_path)) is None

    def test_every_visible_payload_has_its_manifest(self, tmp_path):
        tree = make_tree(1)
        checkpoint.save(str(tmp_path), tree, step=9)
        manifest = checkpoint.load_manifest(str(tmp_path), 9)
        flat_keys = set(manifest["keys"])
        # the manifest records exactly the flattened key set with the
        # documented path encoding: '/'-joined, '#i' for list positions,
        # attribute names for dataclass fields
        assert any(k.startswith("params/dense/#") for k in flat_keys)
        assert "opt/mu" in flat_keys and "opt/count" in flat_keys

    def test_bfloat16_survives_npz_void_encoding(self, tmp_path):
        """np.savez demotes ml_dtypes extension arrays to raw void bytes;
        restore must reinterpret them via the manifest, not fail."""
        tree = {"w": jnp.arange(4, dtype=jnp.bfloat16)}
        checkpoint.save(str(tmp_path), tree, step=1)
        restored, _ = checkpoint.restore(str(tmp_path), _zeros_like(tree))
        assert np.asarray(restored["w"]).dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                      [0.0, 1.0, 2.0, 3.0])
