"""Chaos suite: the elastic outer layer under node churn and process death.

The paper's claim is that AGWU + IDPA absorb heterogeneity and stragglers
(§3); this suite makes the claim testable under *faults*:

* **Churn convergence** — kill k=2 of m=8 nodes mid-training under the
  heap (AGWU), fused-vmap and device-sharded (SGWU) engines; training
  must still converge to the fault-free reference trajectory within
  ``CHURN_LOSS_TOL`` (dead nodes lose their in-flight minibatches, so the
  trajectories diverge slightly — the tolerance bounds how much).
* **Crash-safe resumption** — in-process: break the event stream, build a
  fresh trainer, resume from the state checkpoint, and require the final
  merged weights BIT-identical to an uninterrupted run.  Out-of-process:
  SIGKILL a training subprocess between rounds and require the resumed
  run's final weights to match the uninterrupted run's within 1e-5
  (acceptance bound; on CPU they match exactly).
* **Measured-duration IDPA** — the partitioner must react to *measured*
  per-round durations: perturbing one node's speed (or injecting a
  ``slow`` fault) must shrink that node's next allocation batch.
* **Adversarial AGWU heaps** — duplicate completion timestamps, a
  straggler whose pushes arrive after everyone else finished, and pinned
  Eq. 10 gamma regression values under churn.

AGWU's virtual clock is built from measured wall times, so its pop order
is timing-dependent run to run; every heap assertion here pins per-node
durations (``_pin_durations``) to make the event order — and therefore
the weight math — deterministic.

Set ``REPRO_CHAOS_TRACE=<path>`` to append a JSONL RoundEvent trace of
every churn run (the CI multidevice job uploads it on failure).
"""
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import jax
import numpy as np
import pytest

import repro.core.param_server as param_server_module
from repro.checkpointing import checkpoint
from repro.core.bpt_trainer import BPTTrainer, TrainHooks
from repro.core.faults import FaultEvent, FaultSchedule
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.models.cnn import CNNConfig, cnn_loss, init_cnn

NDEV = len(jax.devices())

# documented tolerance for the churn-vs-reference final loss: losing 2 of
# 8 nodes drops those nodes' minibatches from a handful of merges, which
# perturbs — but must not derail — the trajectory
CHURN_LOSS_TOL = 0.25


def need_devices(m):
    return pytest.mark.skipif(
        NDEV < m, reason=f"needs {m} devices (have {NDEV}); run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _make_trainer(m=4, batches=1, faults=None, speed_factors=None,
                  seed=0, **tc_kwargs):
    cfg = CNNConfig(name="chaos", image_size=8, conv_layers=1, filters=4,
                    fc_layers=1, fc_neurons=32)
    xs, ys = image_dataset(64 * m * 2, size=8, seed=0)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=m,
                     batches=batches)
    tc_kwargs.setdefault("outer_strategy", "sgwu")
    tc = TrainConfig(outer_nodes=m, optimizer="adamw", learning_rate=2e-3,
                     total_steps=100, warmup_steps=5, local_steps=2,
                     seed=seed, **tc_kwargs)
    return BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}), params, ds,
                      tc, batch_size=16, fault_schedule=faults,
                      speed_factors=speed_factors)


ENGINE_KW = {
    "vmap": dict(outer_strategy="sgwu", fused_outer=True),
    "sequential": dict(outer_strategy="sgwu", fused_outer=False),
    "device": dict(outer_strategy="sgwu", device_outer=True),
    "heap": dict(outer_strategy="agwu"),
}


def _pin_durations(tr, per_node):
    """Replace measured wall durations with fixed per-node values so the
    AGWU heap order (and hence the weight math) is deterministic."""
    per_node = np.asarray(per_node, dtype=np.float64)
    orig = tr._local_round

    def pinned(params, opt_state, node, step):
        p, o, loss, _ = orig(params, opt_state, node, step)
        return p, o, loss, float(per_node[node])

    tr._local_round = pinned


def _drain(tr, rounds, hooks=None):
    return list(tr.run(rounds, hooks))


def _final_weights(ev):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(ev.params)]


def _max_diff(ws_a, ws_b):
    return max(float(np.abs(a - b).max())
               for a, b in zip(ws_a, ws_b, strict=True))


def _record_trace(tag, events):
    path = os.environ.get("REPRO_CHAOS_TRACE", "")
    if not path:
        return
    with open(path, "a") as f:
        for ev in events:
            f.write(json.dumps({
                "tag": tag, "round": ev.round, "node": ev.node,
                "loss": float(ev.loss),
                "virtual_clock": float(ev.virtual_clock),
                "sync_wait": float(ev.sync_wait),
                "comm_bytes": int(ev.comm_bytes),
                "node_status": None if ev.node_status is None
                else [float(s) for s in ev.node_status],
                "durations": None if ev.durations is None
                else [float(d) for d in ev.durations],
            }) + "\n")


# ----------------------------------------------------------------------
# churn convergence: kill k=2 of m=8 mid-training
# ----------------------------------------------------------------------
class TestChurnConvergence:
    @pytest.mark.parametrize("seed", [0, 1])   # fixed-seed sweep (CI)
    @pytest.mark.parametrize("engine", [
        "heap", "vmap", pytest.param("device", marks=need_devices(8))])
    def test_k2_of_m8_converges_to_reference(self, engine, seed):
        m, rounds = 8, 4
        # heap indices are push counts (m per virtual round); barrier
        # indices are rounds — both kill nodes 2 and 5 early in the run
        spec = "fail:2@4,fail:5@8" if engine == "heap" \
            else "fail:2@1,fail:5@2"
        faults = FaultSchedule.from_spec(spec, num_nodes=m)

        ref = _make_trainer(m=m, seed=seed, **ENGINE_KW[engine])
        churn = _make_trainer(m=m, seed=seed, faults=faults,
                              **ENGINE_KW[engine])
        if engine == "heap":
            durs = 1.0 + 0.1 * np.arange(m)
            _pin_durations(ref, durs)
            _pin_durations(churn, durs)

        ref_events = _drain(ref, rounds)
        churn_events = _drain(churn, rounds)
        _record_trace(f"churn-{engine}-seed{seed}", churn_events)

        assert churn_events, "churn run produced no events"
        # the dead nodes' remaining work is lost, so the AGWU stream is
        # shorter than the fault-free m*rounds
        if engine == "heap":
            assert len(churn_events) < len(ref_events)
            dead_after = {2: 4, 5: 8}
            for ev in churn_events:
                for node, cutoff in dead_after.items():
                    assert not (ev.node == node and ev.round >= cutoff), \
                        f"dead node {node} pushed at event {ev.round}"
        ref_loss = ref_events[-1].loss
        churn_loss = churn_events[-1].loss
        assert np.isfinite(churn_loss)
        assert abs(churn_loss - ref_loss) < CHURN_LOSS_TOL, \
            (f"{engine}: churn final loss {churn_loss:.4f} diverged from "
             f"reference {ref_loss:.4f} beyond {CHURN_LOSS_TOL}")
        # and training stayed healthy after losing 2 nodes.  AGWU events
        # carry single-node losses, so at this run length the half-run
        # means sit within noise of each other — allow a small margin;
        # an actual post-churn blow-up trips CHURN_LOSS_TOL above long
        # before it trips this.
        losses = [ev.loss for ev in churn_events]
        half = len(losses) // 2
        assert np.mean(losses[half:]) < np.mean(losses[:half]) + 0.05

    def test_rejoined_node_pushes_again(self):
        m = 4
        faults = FaultSchedule.from_spec("fail:1@2,rejoin:1@8", num_nodes=m)
        tr = _make_trainer(m=m, faults=faults, outer_strategy="agwu")
        _pin_durations(tr, np.ones(m))
        events = _drain(tr, 4)
        _record_trace("rejoin-heap", events)
        dead_window = [ev for ev in events if 2 <= ev.round < 8]
        assert all(ev.node != 1 for ev in dead_window)
        assert any(ev.node == 1 and ev.round >= 8 for ev in events), \
            "rejoined node never pushed again"
        # the in-flight push was lost, but the rejoined node REDOES that
        # round (rounds_done never advanced), so the stream is full length
        assert len(events) == 4 * m

    def test_all_dead_raises(self):
        faults = FaultSchedule.from_spec("fail:0@1,fail:1@1", num_nodes=2)
        tr = _make_trainer(m=2, faults=faults, fused_outer=True)
        with pytest.raises(RuntimeError, match="leaves no node alive"):
            _drain(tr, 3)


# ----------------------------------------------------------------------
# node_status / durations observability on the event stream
# ----------------------------------------------------------------------
class TestNodeStatusObservability:
    def test_barrier_status_and_slow_durations(self):
        m = 4
        faults = FaultSchedule(
            [FaultEvent(round=1, node=0, kind="slow", factor=3.0),
             FaultEvent(round=2, node=2, kind="fail")], num_nodes=m)
        tr = _make_trainer(m=m, faults=faults, fused_outer=True)
        events = _drain(tr, 4)
        assert all(ev.node_status is not None for ev in events)
        assert np.all(events[0].node_status == 1.0)
        assert events[1].node_status[0] == 3.0
        assert events[2].node_status[2] == 0.0      # failed
        # the slow factor multiplies the virtual duration exactly
        # (equal speed factors, equal wall share)
        d = events[1].durations
        assert np.isclose(d[0] / d[1], 3.0)
        # a dead node contributes no duration and no sync-wait
        assert events[2].durations[2] == 0.0

    def test_churn_free_runs_emit_no_status(self):
        tr = _make_trainer(m=2, fused_outer=True)
        events = _drain(tr, 2)
        assert all(ev.node_status is None for ev in events)
        assert all(ev.durations is not None for ev in events)

    def test_dead_node_not_charged_comm(self):
        """Eq. 11 counts only transfers that happened: a round with a dead
        node moves 2(m-1) payloads, not 2m."""
        m = 4
        faults = FaultSchedule.from_spec("fail:3@1", num_nodes=m)
        tr = _make_trainer(m=m, faults=faults, fused_outer=True)
        events = _drain(tr, 3)
        per_round = np.diff([0] + [ev.comm_bytes for ev in events])
        wb = events[0].comm_bytes // (2 * m)   # one weight payload
        assert per_round[0] == 2 * m * wb
        assert per_round[1] == 2 * (m - 1) * wb
        assert per_round[2] == 2 * (m - 1) * wb


# ----------------------------------------------------------------------
# in-process crash/resume: bit-identical continuation
# ----------------------------------------------------------------------
class TestCrashResume:
    @pytest.mark.parametrize("engine", ["vmap", "sequential", "heap"])
    def test_resume_is_bit_identical(self, engine, tmp_path):
        rounds, m = 6, 4
        kw = ENGINE_KW[engine]
        durs = 1.0 + 0.25 * np.arange(m)

        ref = _make_trainer(m=m, **kw)
        if engine == "heap":
            _pin_durations(ref, durs)
        ref_events = _drain(ref, rounds)

        # crash: consume part of the stream, then abandon the trainer
        crashed = _make_trainer(m=m, **kw)
        if engine == "heap":
            _pin_durations(crashed, durs)
        hooks = TrainHooks(checkpoint_every=2, checkpoint_dir=str(tmp_path))
        consumed = 0
        stop_at = 8 if engine == "heap" else 3
        for _ev in crashed.run(rounds, hooks):
            consumed += 1
            if consumed >= stop_at:
                break

        # resume: a FRESH trainer (fresh RNG, fresh dataset, fresh engine)
        resumed = _make_trainer(m=m, **kw)
        if engine == "heap":
            _pin_durations(resumed, durs)
        hooks2 = TrainHooks(checkpoint_every=2,
                            checkpoint_dir=str(tmp_path), resume=True)
        res_events = _drain(resumed, rounds, hooks2)

        # resumes from the last state checkpoint (every 2 events), so it
        # replays at most 1 event and never the whole prefix
        last_ckpt = (stop_at // 2) * 2
        assert len(res_events) == len(ref_events) - last_ckpt
        diff = _max_diff(_final_weights(ref_events[-1]),
                         _final_weights(res_events[-1]))
        assert diff == 0.0, \
            f"{engine}: resumed weights differ from uninterrupted (max " \
            f"abs diff {diff:.3e})"
        # the loss trail must splice exactly too
        ref_tail = [ev.loss for ev in ref_events[last_ckpt:]]
        res_tail = [ev.loss for ev in res_events]
        assert ref_tail == res_tail

    def test_resume_with_empty_dir_starts_fresh(self, tmp_path):
        tr = _make_trainer(m=2, fused_outer=True)
        hooks = TrainHooks(checkpoint_every=2, checkpoint_dir=str(tmp_path),
                           resume=True)
        events = _drain(tr, 3)
        assert len(events) == 3

    def test_resume_restores_server_log_and_idpa_state(self, tmp_path):
        """The state checkpoint carries the parameter-server bookkeeping
        and the IDPA allocation state — a resumed run CONTINUES the comm
        accounting and the incremental allocation, it does not restart
        them."""
        m, rounds = 4, 6
        tr = _make_trainer(m=m, batches=2, fused_outer=True)
        hooks = TrainHooks(checkpoint_every=2, checkpoint_dir=str(tmp_path))
        consumed = 0
        for _ev in tr.run(rounds, hooks):
            consumed += 1
            if consumed >= 4:     # state checkpoint for event 4 on disk
                break

        tr2 = _make_trainer(m=m, batches=2, fused_outer=True)
        hooks2 = TrainHooks(checkpoint_every=2,
                            checkpoint_dir=str(tmp_path), resume=True)
        events = _drain(tr2, rounds, hooks2)
        assert len(events) == rounds - 4
        # comm continuity: every SGWU round moves 2m weight payloads, so
        # the resumed run's first event carries FIVE rounds of traffic —
        # the four pre-crash rounds were restored, not reset
        wb = events[0].comm_bytes // (2 * m * 5)
        assert events[0].comm_bytes == 2 * m * 5 * wb
        assert events[-1].comm_bytes == 2 * m * rounds * wb
        # IDPA: both allocation batches landed exactly once across the two
        # processes and the full dataset is covered
        part = tr2.dataset.part
        assert part.done and len(part.history) == part.num_batches
        assert tr2.dataset.totals.sum() == \
            part.batch_size * part.num_batches


# ----------------------------------------------------------------------
# out-of-process: SIGKILL between rounds, resume losslessly
# ----------------------------------------------------------------------
class TestSigkill:
    def _spawn(self, ckpt_dir, resume=False, rounds=6):
        cmd = [sys.executable,
               str(Path(__file__).parent / "chaos_worker.py"),
               "--ckpt-dir", str(ckpt_dir), "--rounds", str(rounds)]
        if resume:
            cmd.append("--resume")
        env = dict(os.environ)
        env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src") + \
            os.pathsep + env.get("PYTHONPATH", "")
        return subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True,
                                env=env)

    def test_sigkill_between_rounds_resumes_losslessly(self, tmp_path):
        from chaos_worker import FINAL_STEP, build_trainer

        ref_dir, kill_dir = tmp_path / "ref", tmp_path / "kill"
        rounds = 6

        # uninterrupted reference
        p = self._spawn(ref_dir, rounds=rounds)
        out, _ = p.communicate(timeout=600)
        assert p.returncode == 0 and "DONE" in out

        # victim: SIGKILL after it reports event 2 (its checkpoint for
        # event 2 is on disk before the line is printed)
        p = self._spawn(kill_dir, rounds=rounds)
        seen = 0
        deadline = time.time() + 600
        for line in p.stdout:
            if line.startswith("EVENT"):
                seen += 1
                if seen >= 3:
                    os.kill(p.pid, signal.SIGKILL)
                    break
            assert time.time() < deadline
        p.wait(timeout=60)
        assert p.returncode != 0, "victim was supposed to die"
        assert checkpoint.latest_step(str(kill_dir)) is not None
        assert checkpoint.latest_step(str(kill_dir), kind="state") \
            is not None

        # resume with the same command line + --resume
        p = self._spawn(kill_dir, resume=True, rounds=rounds)
        out, _ = p.communicate(timeout=600)
        assert p.returncode == 0 and "DONE" in out

        # acceptance: resumed final weights match the uninterrupted run's
        # to 1e-5 (bit-exact on CPU)
        like = build_trainer(4).params0
        w_ref, _ = checkpoint.restore(str(ref_dir), like, step=FINAL_STEP)
        w_res, _ = checkpoint.restore(str(kill_dir), like, step=FINAL_STEP)
        diff = _max_diff(
            [np.asarray(x) for x in jax.tree_util.tree_leaves(w_ref)],
            [np.asarray(x) for x in jax.tree_util.tree_leaves(w_res)])
        assert diff <= 1e-5, f"resumed run diverged: max diff {diff:.3e}"


# ----------------------------------------------------------------------
# measured-duration IDPA: allocation follows observed speed
# ----------------------------------------------------------------------
class TestMeasuredDurationIDPA:
    def test_slow_node_gets_smaller_allocation(self):
        """Perturb one node's speed and watch IDPA re-allocate: the
        allocation is driven by MEASURED RoundEvent durations, not nominal
        frequencies (all frequencies here are equal)."""
        m = 4
        speeds = np.array([1.0, 1.0, 1.0, 6.0])   # node 3: 6x slower
        tr = _make_trainer(m=m, batches=2, fused_outer=True,
                           speed_factors=speeds)
        events = _drain(tr, 3)
        part = tr.dataset.part
        assert part.done and len(part.history) == 2
        # batch 1 was frequency-proportional (equal); batch 2 reacted to
        # the measured durations — the slow node's increment collapses
        inc = part.history[1]
        assert inc[3] < inc[0]
        assert inc[3] < part.history[0][3]
        # and the durations the partitioner saw are on the event stream
        assert events[0].durations is not None
        assert events[0].durations[3] > 3 * events[0].durations[0]

    def test_slow_fault_shrinks_heap_allocation(self):
        """A `slow` fault mid-AGWU-run flows through the measured-duration
        feedback into the next allocation batch."""
        m = 4
        faults = FaultSchedule.from_spec("slow:0@2x8.0", num_nodes=m)
        tr = _make_trainer(m=m, batches=3, outer_strategy="agwu",
                           faults=faults)
        _pin_durations(tr, np.ones(m))
        _drain(tr, 4)
        part = tr.dataset.part
        assert part.done and len(part.history) == 3
        # batch 2 was allocated before the slow fault's durations landed
        # (node 0's slowed round-2 push comes later); batch 3 reacts
        assert abs(part.history[1][0] - part.history[1][1]) <= 1
        inc = part.history[2]
        assert inc[0] < inc[1], \
            "slowed node kept its allocation share despite 8x durations"

    def test_dead_node_keeps_stripe_gets_no_increment(self):
        """§3.3.1: no migration — a dead node keeps what it had, but the
        next allocation batch lands entirely on the survivors."""
        m = 4
        faults = FaultSchedule.from_spec("fail:2@2", num_nodes=m)
        tr = _make_trainer(m=m, batches=2, outer_strategy="agwu",
                           faults=faults)
        _pin_durations(tr, np.ones(m))
        _drain(tr, 4)
        part = tr.dataset.part
        assert part.done and len(part.history) == 2
        first, second = part.history
        assert second[2] == 0                       # nothing new when dead
        assert part.totals[2] == first[2]           # stripe kept
        b = part.num_samples // part.num_batches
        assert second.sum() == b                    # batch fully landed


# ----------------------------------------------------------------------
# adversarial AGWU heaps
# ----------------------------------------------------------------------
# pinned Eq. 10 gamma traces (6 decimals): deterministic given the pinned
# durations — any change to heap ordering, staleness accounting or the
# churn transitions shows up as a drift here.  Regenerate by printing
# `gamma_log` from the matching test.
GAMMAS_STRAGGLER = [0.333333, 0.211942, 0.186324, 0.230237, 0.254275,
                    0.328933, 0.390166, 0.287004, 0.435954]
GAMMAS_CHURN = [0.333333, 0.211942, 0.186324, 0.230237, 0.326496,
                0.290461, 0.351311, 0.312736, 0.4055]


@pytest.fixture
def gamma_log(monkeypatch):
    """Record every Eq. 10 gamma the parameter server computes."""
    rec = []
    orig = param_server_module.agwu_gamma

    def wrapper(*a, **k):
        g = orig(*a, **k)
        rec.append(round(float(g), 6))
        return g

    monkeypatch.setattr(param_server_module, "agwu_gamma", wrapper)
    return rec


class TestAdversarialHeap:
    def test_duplicate_timestamps_order_by_node(self):
        """Identical completion times on every push: the heap must break
        ties deterministically (by node index) and emit every event."""
        m, rounds = 4, 3
        tr = _make_trainer(m=m, outer_strategy="agwu")
        _pin_durations(tr, np.ones(m))
        events = _drain(tr, rounds)
        assert len(events) == m * rounds
        order = [ev.node for ev in events]
        assert order == list(range(m)) * rounds
        # the virtual clock never runs backwards within a node's stream
        for j in range(m):
            clocks = [ev.virtual_clock for ev in events if ev.node == j]
            assert clocks == sorted(clocks)

    def test_straggler_pushes_arrive_after_everyone_finished(self,
                                                            gamma_log):
        """One node 50x slower: its 2nd..Kth pushes pop after every other
        node completed all rounds; its gamma reflects maximal staleness."""
        m, rounds = 3, 3
        tr = _make_trainer(m=m, outer_strategy="agwu")
        _pin_durations(tr, np.array([1.0, 1.0, 50.0]))
        events = _drain(tr, rounds)
        assert len(events) == m * rounds
        assert [ev.node for ev in events[-2:]] == [2, 2]
        fast_done = max(i for i, ev in enumerate(events) if ev.node != 2)
        assert fast_done == m * rounds - 3          # straggler owns the tail
        # Eq. 10 regression pin: the straggler's late pushes carry the
        # smallest gammas of the run (stalest base version)
        assert len(gamma_log) == m * rounds
        straggler_gammas = [g for ev, g in zip(events, gamma_log, strict=True)
                            if ev.node == 2]
        assert min(gamma_log) == min(straggler_gammas)
        assert gamma_log == GAMMAS_STRAGGLER, \
            f"gamma trace drifted: {gamma_log}"

    def test_gamma_pinned_under_churn(self, gamma_log):
        """Eq. 10 staleness weights under fail/rejoin: pinned regression
        values — any change to the heap's churn ordering shows up here."""
        m, rounds = 3, 3
        faults = FaultSchedule.from_spec("fail:1@2,rejoin:1@5", num_nodes=m)
        tr = _make_trainer(m=m, faults=faults, outer_strategy="agwu")
        _pin_durations(tr, np.array([1.0, 1.1, 1.2]))
        events = _drain(tr, rounds)
        _record_trace("gamma-churn", events)
        assert gamma_log == GAMMAS_CHURN, \
            f"gamma trace drifted: {gamma_log}"

    def test_lost_push_never_reaches_server(self):
        """A node that fails mid-round loses exactly its in-flight push:
        the server's update count equals the emitted event count."""
        m, rounds = 4, 3
        faults = FaultSchedule.from_spec("fail:3@2", num_nodes=m)
        tr = _make_trainer(m=m, faults=faults, outer_strategy="agwu")
        _pin_durations(tr, np.ones(m))
        events = _drain(tr, rounds)
        # node 3 died before its first push popped: the survivors' 3*3
        # pushes are the whole stream, node 3 contributes nothing
        assert len(events) == (m - 1) * rounds
        assert all(ev.node != 3 for ev in events)
