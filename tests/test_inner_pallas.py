"""End-to-end inner-layer test: BPT-CNN trains THROUGH the Pallas kernels.

``REPRO_KERNEL_IMPL=pallas`` routes the WHOLE network through the
differentiable Pallas kernels — conv (custom_vjp backward kernels, fused
bias+relu epilogue), pooling (Eq. 15/18 argmax routing) and the FC stack
(§4.1.2 per-block G_FC gradient tasks).  One fused SGWU round under pallas
must reproduce the default (ref) path's loss trajectory and merged weights
on a fixed seed — the acceptance gate that the inner layer is a real
training path, not a forward-only decoration — and a full Table-2
case1/case2 training step must execute with ZERO ref fallbacks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bpt_trainer import BPTTrainer
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.kernels import ops
from repro.models.cnn import (CNNConfig, cnn_forward, cnn_loss, init_cnn,
                              make_case)

# image_size=8 with conv_layers=1 pools once (8 -> 4) and fc_layers=2 puts
# a relu'd hidden FC in the stack, so the trajectory equivalence below
# covers conv + pool + both dense epilogues, not just the conv layer.
CFG = CNNConfig(name="inner", image_size=8, conv_layers=1, filters=4,
                fc_layers=2, fc_neurons=16)


def _run_sgwu(rounds: int = 2, m: int = 2):
    """Fixed-seed fused SGWU run; batches=1 freezes the IDPA allocation so
    wall-time noise cannot change the data both impls see."""
    xs, ys = image_dataset(64 * m, size=8, seed=0)
    params = init_cnn(jax.random.PRNGKey(0), CFG)
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=m, batches=1)
    tc = TrainConfig(outer_strategy="sgwu", outer_nodes=m,
                     optimizer="adamw", learning_rate=2e-3,
                     total_steps=100, warmup_steps=5, local_steps=2,
                     seed=0, fused_outer=True)
    tr = BPTTrainer(lambda p, b: (cnn_loss(p, b, CFG), {}), params, ds, tc,
                    batch_size=16)
    return tr.train(rounds=rounds)


class TestPallasTrainingPath:
    def test_sgwu_round_matches_ref_trajectory(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
        ref_rep = _run_sgwu()
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        pal_rep = _run_sgwu()
        np.testing.assert_allclose(pal_rep.losses, ref_rep.losses,
                                   rtol=1e-4, atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(pal_rep.final_params),
                        jax.tree_util.tree_leaves(ref_rep.final_params),
                        strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-5)

    def test_pallas_grads_nonzero_through_model(self, monkeypatch):
        """The custom_vjp actually reaches the conv filters via jax.grad."""
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        xs, ys = image_dataset(16, size=8, seed=3)
        params = init_cnn(jax.random.PRNGKey(1), CFG)
        batch = {"images": jnp.asarray(xs), "labels": jnp.asarray(ys)}
        grads = jax.grad(lambda p: cnn_loss(p, batch, CFG))(params)
        gw = grads["conv"][0]["w"]
        gb = grads["conv"][0]["b"]
        assert float(jnp.abs(gw).sum()) > 0
        assert float(jnp.abs(gb).sum()) > 0

    def test_forward_impls_agree_through_model(self, monkeypatch):
        xs, _ = image_dataset(8, size=8, seed=4)
        params = init_cnn(jax.random.PRNGKey(2), CFG)
        images = jnp.asarray(xs)
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
        want = cnn_forward(params, images, CFG)
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        got = cnn_forward(params, images, CFG)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


class TestWholeNetworkPallas:
    """Acceptance gate: conv, pooling AND FC execute as Pallas kernels."""

    def test_every_layer_kind_hits_a_pallas_kernel(self, monkeypatch):
        """One grad step invokes all three kernel entry points and the
        fallback log stays empty — no silent ref substitution anywhere."""
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        ops.clear_fallback_log()
        calls = {"conv2d": 0, "max_pool2d": 0, "dense": 0}

        def counting(name, fn):
            def wrapped(*a, **k):
                calls[name] += 1
                return fn(*a, **k)
            return wrapped

        monkeypatch.setattr(ops, "conv2d_pallas",
                            counting("conv2d", ops.conv2d_pallas))
        monkeypatch.setattr(ops, "max_pool2d_pallas",
                            counting("max_pool2d", ops.max_pool2d_pallas))
        monkeypatch.setattr(ops, "dense_pallas",
                            counting("dense", ops.dense_pallas))

        xs, ys = image_dataset(8, size=8, seed=5)
        params = init_cnn(jax.random.PRNGKey(4), CFG)
        batch = {"images": jnp.asarray(xs), "labels": jnp.asarray(ys)}
        grads = jax.grad(lambda p: cnn_loss(p, batch, CFG))(params)
        assert all(n > 0 for n in calls.values()), calls
        assert ops.fallback_events() == {}
        for leaf in jax.tree_util.tree_leaves(grads):
            assert float(jnp.abs(leaf).sum()) > 0

    @pytest.mark.parametrize("case", ["case1", "case2"])
    def test_table2_training_step_pallas_matches_ref(self, case,
                                                     monkeypatch):
        """A full Table-2 network's forward+backward runs through Pallas
        with no fallback, and matches the ref oracles to 1e-4·scale."""
        cfg = make_case(case)
        xs, ys = image_dataset(2, size=32, seed=6)
        params = init_cnn(jax.random.PRNGKey(5), cfg)
        batch = {"images": jnp.asarray(xs), "labels": jnp.asarray(ys)}

        def step(p):
            return jax.value_and_grad(lambda q: cnn_loss(q, batch, cfg))(p)

        monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
        loss_r, grads_r = step(params)
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        ops.clear_fallback_log()
        loss_p, grads_p = step(params)
        assert ops.fallback_events() == {}
        np.testing.assert_allclose(float(loss_p), float(loss_r), rtol=1e-5)
        for g_p, g_r in zip(jax.tree_util.tree_leaves(grads_p),
                            jax.tree_util.tree_leaves(grads_r),
                            strict=True):
            scale = max(float(jnp.abs(g_r).max()), 1.0)
            np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_r),
                                       atol=1e-4 * scale, rtol=1e-4)
