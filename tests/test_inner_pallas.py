"""End-to-end inner-layer test: BPT-CNN trains THROUGH the Pallas kernels.

``REPRO_KERNEL_IMPL=pallas`` routes every model conv through the
differentiable Pallas conv2d (custom_vjp backward kernels, fused bias+relu
epilogue).  One fused SGWU round under pallas must reproduce the default
(ref) path's loss trajectory and merged weights on a fixed seed — the
acceptance gate that the inner layer is a real training path, not a
forward-only decoration.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bpt_trainer import BPTTrainer
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.models.cnn import CNNConfig, cnn_forward, cnn_loss, init_cnn

CFG = CNNConfig(name="inner", image_size=8, conv_layers=1, filters=4,
                fc_layers=1, fc_neurons=16)


def _run_sgwu(rounds: int = 2, m: int = 2):
    """Fixed-seed fused SGWU run; batches=1 freezes the IDPA allocation so
    wall-time noise cannot change the data both impls see."""
    xs, ys = image_dataset(64 * m, size=8, seed=0)
    params = init_cnn(jax.random.PRNGKey(0), CFG)
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=m, batches=1)
    tc = TrainConfig(outer_strategy="sgwu", outer_nodes=m,
                     optimizer="adamw", learning_rate=2e-3,
                     total_steps=100, warmup_steps=5, local_steps=2,
                     seed=0, fused_outer=True)
    tr = BPTTrainer(lambda p, b: (cnn_loss(p, b, CFG), {}), params, ds, tc,
                    batch_size=16)
    return tr.train(rounds=rounds)


class TestPallasTrainingPath:
    def test_sgwu_round_matches_ref_trajectory(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
        ref_rep = _run_sgwu()
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        pal_rep = _run_sgwu()
        np.testing.assert_allclose(pal_rep.losses, ref_rep.losses,
                                   rtol=1e-4, atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(pal_rep.final_params),
                        jax.tree_util.tree_leaves(ref_rep.final_params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=1e-5)

    def test_pallas_grads_nonzero_through_model(self, monkeypatch):
        """The custom_vjp actually reaches the conv filters via jax.grad."""
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        xs, ys = image_dataset(16, size=8, seed=3)
        params = init_cnn(jax.random.PRNGKey(1), CFG)
        batch = {"images": jnp.asarray(xs), "labels": jnp.asarray(ys)}
        grads = jax.grad(lambda p: cnn_loss(p, batch, CFG))(params)
        gw = grads["conv"][0]["w"]
        gb = grads["conv"][0]["b"]
        assert float(jnp.abs(gw).sum()) > 0
        assert float(jnp.abs(gb).sum()) > 0

    def test_forward_impls_agree_through_model(self, monkeypatch):
        xs, _ = image_dataset(8, size=8, seed=4)
        params = init_cnn(jax.random.PRNGKey(2), CFG)
        images = jnp.asarray(xs)
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "ref")
        want = cnn_forward(params, images, CFG)
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        got = cnn_forward(params, images, CFG)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)
