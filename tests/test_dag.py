"""Task DAG + priority list scheduling (Alg. 4.2) tests."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dag import (TaskDAG, choose_fc_block, choose_oc_tile,
                            cnn_training_dag, conv_grid_tasks,
                            conv_layer_tasks, conv_output_shape,
                            fc_grid_tasks, priority_schedule)


class TestConvDecomposition:
    def test_eq12_output_shape(self):
        # (32 - 3 + 2*1)/1 + 1 = 32 (SAME-ish)
        assert conv_output_shape(32, 32, 3, 3, 1, 1) == (32, 32)
        assert conv_output_shape(28, 28, 5, 5, 1, 0) == (24, 24)

    def test_eq13_task_count(self):
        dag = TaskDAG()
        tids = conv_layer_tasks(dag, 8, 8, 3, 3, pad=1, tile=1)
        assert len(tids) == 8 * 8            # K_C = H_a * W_a

    def test_tiling_reduces_tasks(self):
        dag = TaskDAG()
        tids = conv_layer_tasks(dag, 8, 8, 3, 3, pad=1, tile=4)
        assert len(tids) == 4                # (8/4)^2


class TestExecutedGrid:
    """PT_Conv at pallas-grid granularity + the oc_tile cost model."""

    def test_grid_task_count_and_cost(self):
        dag = TaskDAG()
        tids = conv_grid_tasks(dag, batch=4, cout=16, oc_tile=8,
                               cost_per_channel=2.0)
        assert len(tids) == 4 * (16 // 8)
        assert all(dag.tasks[t].cost == 16.0 for t in tids)

    def test_grid_rejects_non_divisor(self):
        with pytest.raises(ValueError):
            conv_grid_tasks(TaskDAG(), batch=1, cout=16, oc_tile=5)

    def test_choose_tile_divides_cout(self):
        for batch, cout in [(1, 4), (2, 16), (8, 32), (3, 12), (16, 128)]:
            tile = choose_oc_tile(batch, cout)
            assert cout % tile == 0 and tile >= 1

    def test_small_cout_stays_untiled(self):
        # below min_tile the MXU-lane floor keeps one task per image
        assert choose_oc_tile(4, 4) == 4

    def test_wide_conv_tiles_to_fill_workers(self):
        # B=1, Cout=128, 8 workers: untiled = 1 task (makespan 128);
        # tile 16 = 8 tasks in parallel (makespan 16) — the model must tile.
        assert choose_oc_tile(1, 128, workers=8) == 16

    def test_saturated_batch_prefers_big_tiles(self):
        # B=64 images already saturate 8 workers; splitting channels only
        # adds tasks without shortening the critical resource.
        assert choose_oc_tile(64, 32, workers=8) == 32

    def test_chosen_tile_schedules_no_worse_than_untiled(self):
        for batch, cout in [(1, 64), (2, 32), (5, 16)]:
            tile = choose_oc_tile(batch, cout, workers=8)
            def makespan(t, batch=batch, cout=cout):
                dag = TaskDAG()
                conv_grid_tasks(dag, batch, cout, t)
                return priority_schedule(dag, 8).makespan
            assert makespan(tile) <= makespan(cout) + 1e-9


class TestFCBlockModel:
    """G_FC at pallas-grid granularity + the choose_fc_block cost model
    (mirrors TestExecutedGrid for the dense kernel's task list)."""

    def test_grid_task_count_and_cost(self):
        dag = TaskDAG()
        tids = fc_grid_tasks(dag, d_out=64, block=16, cost_per_neuron=2.0)
        assert len(tids) == 64 // 16
        assert all(dag.tasks[t].cost == 32.0 for t in tids)

    def test_grid_rejects_non_divisor(self):
        with pytest.raises(ValueError):
            fc_grid_tasks(TaskDAG(), d_out=64, block=5)

    @settings(max_examples=30, deadline=None)
    @given(d_out=st.integers(1, 2048), workers=st.integers(1, 16))
    def test_choose_block_divides_d_out(self, d_out, workers):
        block = choose_fc_block(d_out, workers=workers)
        assert d_out % block == 0 and block >= 1

    def test_small_d_out_stays_whole(self):
        # below min_block the MXU-lane floor keeps one task per layer
        assert choose_fc_block(4) == 4
        assert choose_fc_block(10) == 10     # no divisor in [8, 10)

    def test_wide_fc_blocks_to_fill_workers(self):
        # d_out=128, 8 workers: one whole-layer task = makespan 128;
        # block 16 = 8 parallel tasks (makespan 16) — the model must split.
        assert choose_fc_block(128, workers=8) == 16

    def test_single_worker_prefers_whole_layer(self):
        # serial makespans all equal d_out; the largest block wins the tie
        assert choose_fc_block(512, workers=1) == 512

    def test_chosen_block_schedules_no_worse_than_whole(self):
        for d_out in (64, 500, 1000):
            block = choose_fc_block(d_out, workers=8)

            def makespan(bl, d_out=d_out):
                dag = TaskDAG()
                fc_grid_tasks(dag, d_out, bl)
                return priority_schedule(dag, 8).makespan

            assert makespan(block) <= makespan(d_out) + 1e-9


class TestPriorities:
    def test_upstream_higher_than_downstream(self):
        dag = TaskDAG()
        a = dag.add("a", 1.0)
        b = dag.add("b", 1.0, deps=[a])
        c = dag.add("c", 1.0, deps=[b])
        dag.mark_priorities()
        assert dag.tasks[a].priority > dag.tasks[b].priority > \
            dag.tasks[c].priority

    def test_same_level_same_priority(self):
        dag = TaskDAG()
        a = dag.add("a", 1.0)
        b1 = dag.add("b1", 1.0, deps=[a])
        b2 = dag.add("b2", 2.0, deps=[a])
        dag.mark_priorities()
        assert dag.tasks[b1].priority == dag.tasks[b2].priority

    def test_cycle_detection(self):
        dag = TaskDAG()
        a = dag.add("a", 1.0, deps=[1])      # forward ref to b
        b = dag.add("b", 1.0, deps=[a])
        with pytest.raises(ValueError):
            dag.mark_priorities()


class TestSchedule:
    def _dag(self):
        return cnn_training_dag([
            {"kind": "conv", "hx": 8, "wx": 8, "hf": 3, "wf": 3, "depth": 3},
            {"kind": "pool", "hx": 8, "wx": 8, "k": 2},
            {"kind": "fc", "in": 128, "out": 64},
        ], tile=2)

    def test_bounds(self):
        dag = self._dag()
        for k in (1, 2, 4, 8):
            r = priority_schedule(dag, k)
            assert r.makespan >= r.critical_path - 1e-9
            assert r.makespan <= dag.total_work() + 1e-9
            assert r.speedup <= k + 1e-9

    def test_single_thread_is_serial(self):
        dag = self._dag()
        r = priority_schedule(dag, 1)
        assert r.makespan == pytest.approx(dag.total_work())
        assert r.speedup == pytest.approx(1.0)

    def test_more_threads_not_slower(self):
        dag = self._dag()
        m1 = priority_schedule(dag, 2).makespan
        m2 = priority_schedule(dag, 8).makespan
        assert m2 <= m1 + 1e-9

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 99), threads=st.integers(1, 12),
           n=st.integers(2, 40))
    def test_random_dags_complete(self, seed, threads, n):
        """Alg. 4.2 schedules every DAG completely and within bounds."""
        rng = np.random.default_rng(seed)
        dag = TaskDAG()
        tids = []
        for i in range(n):
            k = rng.integers(0, min(i, 3) + 1)
            deps = rng.choice(tids, size=k, replace=False) if tids and k else []
            tids.append(dag.add(f"t{i}", float(rng.random() + 0.1),
                                deps=list(deps)))
        r = priority_schedule(dag, threads)
        assert r.critical_path - 1e-9 <= r.makespan <= dag.total_work() + 1e-9
        # work conservation: busy time sums to total work
        assert r.thread_busy.sum() == pytest.approx(dag.total_work())
