"""SGWU (Eq. 7) / AGWU (Eq. 9-10) math tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.gwu import agwu_gamma, agwu_update, sgwu_merge
from repro.core.param_server import ParameterServer


def tree(val):
    return {"a": jnp.full((3, 2), val, jnp.float32),
            "b": {"c": jnp.full((4,), 2 * val, jnp.float32)}}


class TestSGWU:
    def test_eq7_weighted_average(self):
        merged = sgwu_merge([tree(1.0), tree(3.0)], [0.25, 0.75])
        np.testing.assert_allclose(merged["a"], 0.25 * 1 + 0.75 * 3, rtol=1e-6)
        np.testing.assert_allclose(merged["b"]["c"], 2 * 2.5, rtol=1e-6)

    def test_equal_weights_is_mean(self):
        merged = sgwu_merge([tree(0.0), tree(10.0)], [0.5, 0.5])
        np.testing.assert_allclose(merged["a"], 5.0, rtol=1e-6)

    def test_zero_accuracy_degrades_to_uniform(self):
        merged = sgwu_merge([tree(0.0), tree(4.0)], [0.0, 0.0])
        np.testing.assert_allclose(merged["a"], 2.0, rtol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.floats(0.05, 1.0), min_size=2, max_size=6),
           st.integers(0, 99))
    def test_convexity(self, qs, seed):
        """The merge is a convex combination: bounded by min/max leaf."""
        rng = np.random.default_rng(seed)
        vals = rng.standard_normal(len(qs))
        merged = sgwu_merge([tree(float(v)) for v in vals], qs)
        assert float(merged["a"].min()) >= vals.min() - 1e-5
        assert float(merged["a"].max()) <= vals.max() + 1e-5


class TestAGWU:
    def test_eq10_update(self):
        g = tree(1.0)
        local = tree(2.0)
        base = tree(1.0)          # worker trained from the current global
        out = agwu_update(g, local, base, gamma=0.5, accuracy=0.8)
        # W + 0.5*0.8*(2-1) = W + 0.4
        np.testing.assert_allclose(out["a"], 1.4, rtol=1e-6)

    def test_gamma_fresh_vs_stale(self):
        """Fresh local weights (k close to i-1) get more mass (Eq. 9)."""
        fresh = agwu_gamma(9, 10, outstanding_versions=[2])
        stale = agwu_gamma(2, 10, outstanding_versions=[9])
        assert fresh > stale
        assert 0 < stale < fresh <= 1.0

    def test_gamma_single_worker_is_one(self):
        assert agwu_gamma(5, 6, outstanding_versions=[]) == pytest.approx(1.0)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 20), st.integers(1, 21),
           st.lists(st.integers(0, 20), max_size=5))
    def test_gamma_in_unit_interval(self, k, latest, outstanding):
        g = agwu_gamma(min(k, latest), max(latest, 1), outstanding)
        assert 0.0 < g <= 1.0


class TestParameterServer:
    def test_comm_accounting_eq11(self):
        """C = 2 c_w m K: every round trip is 2 weight transfers."""
        w0 = tree(0.0)
        ps = ParameterServer(w0, num_workers=3)
        K = 4
        for _it in range(K):
            for j in range(3):
                w, _ = ps.pull(j)
                ps.push_agwu(j, tree(1.0), accuracy=0.5)
        assert ps.comm_bytes == ps.expected_comm_bytes(K)

    def test_versions_advance(self):
        ps = ParameterServer(tree(0.0), num_workers=2)
        ps.pull(0)
        ps.pull(1)
        ps.push_agwu(0, tree(1.0), 1.0)
        assert ps.version == 1
        ps.push_agwu(1, tree(1.0), 1.0)
        assert ps.version == 2

    def test_push_before_pull_raises(self):
        ps = ParameterServer(tree(0.0), num_workers=1)
        with pytest.raises(RuntimeError):
            ps.push_agwu(0, tree(1.0), 1.0)

    def test_sgwu_requires_all_workers(self):
        ps = ParameterServer(tree(0.0), num_workers=2)
        ps.pull(0)
        with pytest.raises(RuntimeError):
            ps.push_sgwu([(0, tree(1.0), 1.0)])
