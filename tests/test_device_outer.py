"""Device-sharded outer layer equivalence suite.

The `device_outer` path places the node axis on a real `nodes` mesh
(shard_map round, psum merge, device-resident ParameterServer) and must
reproduce the fused-vmap emulation's loss trajectory and merged weights.
Multi-device cases need forced host devices — the CI ``multidevice`` job
runs this file under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
— and skip on single-device runs; the fallback and delta-push tests run
anywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bpt_trainer import BPTTrainer
from repro.core.gwu import (sgwu_merge_and_rebroadcast_sharded,
                            sgwu_merge_stacked, tree_sub)
from repro.core.param_server import ParameterServer
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.launch.mesh import MESHES, make_nodes_mesh
from repro.models.cnn import CNNConfig, cnn_loss, init_cnn

NDEV = len(jax.devices())


def need_devices(m):
    return pytest.mark.skipif(
        NDEV < m, reason=f"needs {m} devices (have {NDEV}); run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _run_sgwu(m: int, *, device: bool, uneven: bool = False, rounds: int = 3,
              hetero: bool = False, mesh_name: str = "",
              plan_family: str = ""):
    """One SGWU run on a fixed seed; batches=1 freezes the IDPA allocation
    so both paths see identical data regardless of wall time.  ``hetero``
    gives the nodes a frequency gradient, so the frozen first-batch
    allocation (Eq. 2) — and with it the uneven stripe sizes — differ.
    ``mesh_name`` names a MESHES entry (a 2-D ``nodesNxmodelK`` entry
    turns on the per-layer planner; ``plan_family`` forces its family)."""
    cfg = CNNConfig(name="equiv", image_size=8, conv_layers=1, filters=4,
                    fc_layers=1, fc_neurons=32)
    xs, ys = image_dataset(64 * m * 2, size=8, seed=0)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    freqs = np.linspace(1.0, 2.0, m) if hetero else None
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=m, batches=1,
                     frequencies=freqs)
    tc = TrainConfig(outer_strategy="sgwu", outer_nodes=m,
                     optimizer="adamw", learning_rate=2e-3,
                     total_steps=100, warmup_steps=5, local_steps=2,
                     seed=0, device_outer=device, uneven_batches=uneven,
                     mesh_name=mesh_name)
    tr = BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}), params, ds, tc,
                    batch_size=32, model_cfg=cfg, plan_family=plan_family)
    return tr.train(rounds=rounds)


def _assert_reports_close(dev, ref, rtol=1e-5, atol=1e-6):
    np.testing.assert_allclose(dev.losses, ref.losses, rtol=rtol, atol=atol)
    for a, b in zip(jax.tree_util.tree_leaves(dev.final_params),
                    jax.tree_util.tree_leaves(ref.final_params),
                    strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=rtol, atol=atol)


class TestDeviceVmapEquivalence:
    """device-sharded SGWU ≡ fused vmap (the PR's correctness bar)."""

    @need_devices(2)
    @pytest.mark.parametrize("uneven", [False, True])
    def test_m2(self, uneven):
        dev = _run_sgwu(2, device=True, uneven=uneven, hetero=uneven)
        ref = _run_sgwu(2, device=False, uneven=uneven, hetero=uneven)
        assert dev.backend == "device" and ref.backend == "vmap"
        _assert_reports_close(dev, ref)

    @need_devices(8)
    @pytest.mark.parametrize("uneven", [False, True])
    def test_m8(self, uneven):
        """The acceptance bar: ≥3 rounds at m=8 within 1e-5."""
        dev = _run_sgwu(8, device=True, uneven=uneven, hetero=uneven,
                        rounds=4)
        ref = _run_sgwu(8, device=False, uneven=uneven, hetero=uneven,
                        rounds=4)
        assert dev.backend == "device" and ref.backend == "vmap"
        _assert_reports_close(dev, ref)

    @need_devices(2)
    def test_comm_bytes_accounting_unchanged(self):
        dev = _run_sgwu(2, device=True)
        ref = _run_sgwu(2, device=False)
        assert dev.comm_bytes == ref.comm_bytes

    @need_devices(2)
    def test_global_weights_stay_device_resident(self):
        """The merged weights never funnel to host: they come back as ONE
        jax.Array replicated across every mesh device."""
        dev = _run_sgwu(2, device=True)
        for leaf in jax.tree_util.tree_leaves(dev.final_params):
            assert isinstance(leaf, jax.Array)
            assert leaf.sharding.is_fully_replicated
            assert len(leaf.sharding.device_set) == 2


class TestHybridMeshEquivalence:
    """2-D hybrid-mesh SGWU ≡ 1-D device outer ≡ fused vmap (the planner
    PR's acceptance bar): the per-layer inner parallelism over `model`
    must not move the training trajectory at all — the batch family's
    weighted psum recombine and the channel family's collective
    transposes are exact, not approximate."""

    @need_devices(8)
    def test_4x2_matches_1d_and_vmap(self):
        """The ISSUE's named contract: (nodes=4, model=2) on 8 devices."""
        from repro.kernels import ops
        ops.clear_fallback_log()
        hyb = _run_sgwu(4, device=True, mesh_name="nodes4xmodel2",
                        rounds=4)
        dev = _run_sgwu(4, device=True, rounds=4)
        ref = _run_sgwu(4, device=False, rounds=4)
        assert hyb.backend == "device" and dev.backend == "device"
        assert ref.backend == "vmap"
        _assert_reports_close(hyb, dev)
        _assert_reports_close(hyb, ref)
        if ops.default_impl() == "pallas":
            # the all-Pallas contract extends to the hybrid rounds
            assert ops.fallback_events() == {}

    @need_devices(8)
    def test_4x2_uneven_masked_stripes(self):
        """Masked stripes recombine exactly too: grad of Σlm/Σm is
        psum(M_s·g_s)/psum(M_s), which grad_combine implements."""
        hyb = _run_sgwu(4, device=True, mesh_name="nodes4xmodel2",
                        uneven=True, hetero=True)
        ref = _run_sgwu(4, device=False, uneven=True, hetero=True)
        _assert_reports_close(hyb, ref)

    @need_devices(8)
    def test_4x2_channel_family(self):
        """Forced column-parallel fc (Megatron dataflow) ≡ vmap."""
        hyb = _run_sgwu(4, device=True, mesh_name="nodes4xmodel2",
                        plan_family="channel")
        ref = _run_sgwu(4, device=False)
        _assert_reports_close(hyb, ref)

    @need_devices(4)
    def test_2x2_matches_vmap(self):
        hyb = _run_sgwu(2, device=True, mesh_name="nodes2xmodel2")
        ref = _run_sgwu(2, device=False)
        _assert_reports_close(hyb, ref)


class TestFallback:
    def test_too_few_devices_falls_back_to_vmap(self):
        m = 2 * NDEV          # always more nodes than devices
        rep = _run_sgwu(m, device=True, rounds=2)
        assert rep.backend == "vmap"
        ref = _run_sgwu(m, device=False, rounds=2)
        _assert_reports_close(rep, ref)

    def test_bad_mesh_name_raises(self):
        cfg = CNNConfig(name="t", image_size=8, conv_layers=1, filters=4,
                        fc_layers=1, fc_neurons=16)
        xs, ys = image_dataset(128, size=8, seed=0)
        ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=2,
                         batches=1)
        tc = TrainConfig(outer_strategy="sgwu", outer_nodes=2,
                         device_outer=True, mesh_name="tiny")
        tr = BPTTrainer(
            lambda p, b: (cnn_loss(p, b, cfg), {}),
            init_cnn(jax.random.PRNGKey(0), cfg), ds, tc, batch_size=8)
        if NDEV >= 4:         # mesh builds, then fails the axis check
            with pytest.raises(ValueError, match="nodes"):
                tr.train(rounds=1)
        else:                 # too few devices: transparent fallback first
            assert tr.train(rounds=1).backend == "vmap"


class TestShardedMerge:
    """gwu.sgwu_merge_and_rebroadcast_sharded ≡ host-side Eq. 7 merge."""

    def _stacked(self, m, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        return {"w": jax.random.normal(ks[0], (m, 4, 3)),
                "b": {"x": jax.random.normal(ks[1], (m, 5)),
                      "s": jax.random.normal(ks[2], (m,))}}

    @need_devices(2)
    @pytest.mark.parametrize("m", [2, 8])
    def test_matches_host_merge(self, m):
        if NDEV < m:
            pytest.skip(f"needs {m} devices")
        mesh = make_nodes_mesh(m)
        sharding = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("nodes"))
        qs = list(np.linspace(0.2, 1.0, m))
        want = sgwu_merge_stacked(self._stacked(m), qs)
        stacked = jax.device_put(self._stacked(m), sharding)
        merged, new_stacked = sgwu_merge_and_rebroadcast_sharded(
            stacked, qs, mesh)
        for a, b in zip(jax.tree_util.tree_leaves(merged),
                        jax.tree_util.tree_leaves(want), strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
        # the rebroadcast stack holds m replicas of the merged tree
        for leaf, mg in zip(jax.tree_util.tree_leaves(new_stacked),
                            jax.tree_util.tree_leaves(merged),
                            strict=True):
            np.testing.assert_allclose(
                np.asarray(leaf),
                np.broadcast_to(np.asarray(mg)[None], leaf.shape),
                rtol=1e-6)

    @need_devices(2)
    def test_server_device_mode_matches_host_mode(self):
        mesh = make_nodes_mesh(2)
        qs = [0.3, 0.7]
        host = ParameterServer(self._stacked(1)["b"], num_workers=2)
        dev = ParameterServer(self._stacked(1)["b"], num_workers=2,
                              mesh=mesh)
        for ps in (host, dev):
            ps.pull_all_stacked()
        def sub():     # fresh each time: both pushes DONATE their stack
            return jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                self._stacked(1, seed=1)["b"], self._stacked(1, seed=2)["b"])
        host.push_sgwu_stacked(sub(), qs)
        dev.push_sgwu_stacked(
            jax.device_put(sub(), jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("nodes"))), qs)
        for a, b in zip(jax.tree_util.tree_leaves(host.global_weights),
                        jax.tree_util.tree_leaves(dev.global_weights),
                        strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
        assert host.comm_bytes == dev.comm_bytes
        assert host.version == dev.version
        # pull after push hands out the sharded replica cache, advanced
        again, version = dev.pull_all_stacked()
        assert version == 1
        for leaf, mg in zip(jax.tree_util.tree_leaves(again),
                            jax.tree_util.tree_leaves(dev.global_weights),
                            strict=True):
            np.testing.assert_allclose(
                np.asarray(leaf),
                np.broadcast_to(np.asarray(mg)[None], leaf.shape),
                rtol=1e-6)


class TestAgwuDeviceDeltas:
    def _tree(self, v):
        return {"a": jnp.full((3, 2), v, jnp.float32),
                "b": jnp.full((4,), 2 * v, jnp.float32)}

    def test_delta_push_matches_full_push(self):
        """push_agwu_delta(W_j - W(k)) ≡ push_agwu(W_j): same math split
        at the subtraction, same bookkeeping."""
        full = ParameterServer(self._tree(0.5), num_workers=2)
        delta = ParameterServer(self._tree(0.5), num_workers=2)
        for ps in (full, delta):
            for j in range(2):
                ps.pull(j)
        dev = jax.devices()[-1]       # node-resident on the LAST device
        local = jax.device_put(self._tree(1.5), dev)
        base = jax.device_put(self._tree(0.5), dev)
        full.push_agwu(0, self._tree(1.5), 0.7, virtual_time=1.0)
        delta.push_agwu_delta(0, tree_sub(local, base), 0.7,
                              virtual_time=1.0)
        for a, b in zip(jax.tree_util.tree_leaves(full.global_weights),
                        jax.tree_util.tree_leaves(delta.global_weights),
                        strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
        assert full.comm_bytes == delta.comm_bytes
        assert full.version == delta.version
        assert [s.base_version for s in full.update_log] == \
            [s.base_version for s in delta.update_log]

    def test_delta_push_never_pulled(self):
        ps = ParameterServer(self._tree(0.0), num_workers=1)
        with pytest.raises(RuntimeError, match="never pulled"):
            ps.push_agwu_delta(0, self._tree(0.1), 1.0)

    @need_devices(2)
    def test_agwu_trainer_device_mode_runs(self):
        cfg = CNNConfig(name="t", image_size=8, conv_layers=1, filters=4,
                        fc_layers=1, fc_neurons=32)
        xs, ys = image_dataset(256, size=8, seed=0)
        ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=2,
                         batches=1)
        tc = TrainConfig(outer_strategy="agwu", outer_nodes=2,
                         optimizer="adamw", learning_rate=2e-3,
                         total_steps=100, warmup_steps=5, local_steps=1,
                         seed=0, device_outer=True)
        tr = BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}),
                        init_cnn(jax.random.PRNGKey(0), cfg), ds, tc,
                        batch_size=16)
        rep = tr.train(rounds=2)
        assert rep.backend == "heap-device"
        assert np.isfinite(rep.losses).all()
        assert rep.comm_bytes > 0


class TestUnevenBatches:
    def _ds(self, m=4, n=512, hetero=True):
        xs, ys = image_dataset(n, size=8, seed=3)
        freqs = np.linspace(1.0, 2.0, m) if hetero else None
        return IDPADataset({"images": xs, "labels": ys}, num_nodes=m,
                           batches=1, frequencies=freqs)

    def test_sizes_proportional_to_allocation(self):
        ds = self._ds()
        sizes = ds.node_round_batch_sizes(32)
        totals = ds.totals
        assert sizes[np.argmax(totals)] == 32        # fastest: full batch
        assert (sizes >= 1).all() and (sizes <= 32).all()
        order = np.argsort(totals)
        assert (np.diff(sizes[order]) >= 0).all()    # monotone in stripe

    def test_mask_shape_and_padding(self):
        ds = self._ds()
        out = ds.stacked_round_batches(32, 2, np.random.default_rng(0),
                                       uneven=True)
        assert out["mask"].shape == (4, 2, 32)
        sizes = ds.node_round_batch_sizes(32)
        for j in range(4):
            for s in range(2):
                assert out["mask"][j, s].sum() == sizes[j]
                # padded region cycles the real samples of the stripe
                assert out["images"][j, s].shape == (32, 8, 8, 3)

    def test_uniform_draw_order_unchanged(self):
        """uneven=False must consume the RNG exactly like before (and like
        the sequential node_batch loop) and emit NO mask leaf."""
        ds = self._ds(hetero=False)
        out = ds.stacked_round_batches(16, 2, np.random.default_rng(7))
        assert "mask" not in out
        rng = np.random.default_rng(7)
        for j in range(4):
            for s in range(2):
                want = ds.node_batch(j, 16, rng)
                np.testing.assert_array_equal(out["images"][j, s],
                                              want["images"])

    def test_masked_loss_ignores_padding(self):
        cfg = CNNConfig(name="t", image_size=8, conv_layers=1, filters=4,
                        fc_layers=1, fc_neurons=16)
        params = init_cnn(jax.random.PRNGKey(0), cfg)
        xs, ys = image_dataset(8, size=8, seed=0)
        real = {"images": jnp.asarray(xs[:4]), "labels": jnp.asarray(ys[:4])}
        padded = {"images": jnp.asarray(np.resize(xs[:4], (8, 8, 8, 3))),
                  "labels": jnp.asarray(np.resize(ys[:4], (8,))),
                  "mask": jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)}
        np.testing.assert_allclose(float(cnn_loss(params, padded, cfg)),
                                   float(cnn_loss(params, real, cfg)),
                                   rtol=1e-6)
        ones = dict(real, mask=jnp.ones((4,), jnp.float32))
        np.testing.assert_allclose(float(cnn_loss(params, ones, cfg)),
                                   float(cnn_loss(params, real, cfg)),
                                   rtol=1e-6)

    @pytest.mark.parametrize("tc_kwargs", [
        dict(outer_strategy="sgwu", fused_outer=False),   # sequential loop
        dict(outer_strategy="agwu"),                      # per-node heap
        dict(outer_strategy="sync"),                      # single-node DP
    ])
    def test_non_stacked_paths_reject_uneven(self, tc_kwargs):
        """Only the stacked SGWU rounds realize the masked stripes; every
        other path must fail loudly rather than silently train uniform."""
        ds = self._ds(m=2)
        tc = TrainConfig(outer_nodes=2, uneven_batches=True, **tc_kwargs)
        cfg = CNNConfig(name="t", image_size=8, conv_layers=1, filters=4,
                        fc_layers=1, fc_neurons=16)
        tr = BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}),
                        init_cnn(jax.random.PRNGKey(0), cfg), ds, tc,
                        batch_size=8)
        with pytest.raises(ValueError, match="uneven"):
            tr.train(rounds=1)


class TestNodesMeshFamily:
    def test_meshes_entries(self):
        for m in (2, 4, 8, 16):
            shape, axes = MESHES[f"nodes{m}"]
            assert shape == (m,) and axes == ("nodes",)

    def test_make_nodes_mesh(self):
        if NDEV < 2:
            with pytest.raises(RuntimeError, match="nodes mesh"):
                make_nodes_mesh(2)
        else:
            mesh = make_nodes_mesh(2)
            assert mesh.shape == {"nodes": 2}

    def test_bad_count(self):
        with pytest.raises(ValueError):
            make_nodes_mesh(0)
