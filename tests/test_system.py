"""End-to-end behaviour tests for the paper's system.

1. BPT-CNN training (the paper's pipeline: IDPA + AGWU over a real CNN)
   improves accuracy and beats random chance.
2. The LM side: a reduced assigned arch trains end-to-end via the BPT
   trainer and the loss goes down.
3. Serving: greedy generation via the decode path produces tokens.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core.bpt_trainer import BPTTrainer
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset, host_batch, pack_sequences
from repro.data.synthetic import image_dataset, lm_corpus
from repro.models import lm
from repro.models.cnn import CNNConfig, cnn_accuracy, cnn_loss, init_cnn


@pytest.fixture(scope="module")
def cnn_setup():
    cfg = CNNConfig(name="e2e", image_size=16, conv_layers=2, filters=8,
                    fc_layers=2, fc_neurons=64)
    xs, ys = image_dataset(1500, size=16, seed=0)
    xe, ye = image_dataset(400, size=16, seed=9)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    eval_batch = {"images": jnp.asarray(xe), "labels": jnp.asarray(ye)}
    eval_fn = jax.jit(lambda p: cnn_accuracy(p, eval_batch, cfg))
    return cfg, xs, ys, params, eval_fn


def _train(cfg, xs, ys, params, eval_fn, strategy, rounds=8):
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=3, batches=3,
                     frequencies=[1.0, 1.5, 2.0])
    tc = TrainConfig(outer_strategy=strategy, outer_nodes=3,
                     optimizer="adamw", learning_rate=2e-3,
                     total_steps=300, warmup_steps=10, local_steps=3)
    tr = BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}), params, ds, tc,
                    batch_size=64, eval_fn=eval_fn,
                    speed_factors=[1.0, 1.4, 1.9])
    return tr.train(rounds=rounds)


class TestBPTCNNEndToEnd:
    def test_agwu_learns_above_chance(self, cnn_setup):
        cfg, xs, ys, params, eval_fn = cnn_setup
        rep = _train(cfg, xs, ys, params, eval_fn, "agwu")
        final_acc = rep.accuracies[-1][1]
        assert final_acc > 0.3            # 10 classes, chance = 0.1
        assert rep.sync_wait == 0.0       # AGWU: no synchronisation waiting

    def test_sgwu_learns_and_waits(self, cnn_setup):
        cfg, xs, ys, params, eval_fn = cnn_setup
        rep = _train(cfg, xs, ys, params, eval_fn, "sgwu", rounds=10)
        # SGWU's plain averaging converges slower than AGWU; chance = 0.1
        assert rep.accuracies[-1][1] > 0.2
        assert rep.sync_wait > 0.0        # heterogeneous nodes wait

    def test_comm_positive(self, cnn_setup):
        cfg, xs, ys, params, eval_fn = cnn_setup
        rep = _train(cfg, xs, ys, params, eval_fn, "agwu", rounds=3)
        assert rep.comm_bytes > 0


class TestLMEndToEnd:
    def test_reduced_arch_loss_decreases(self):
        cfg = configs.get_reduced("phi3-mini-3.8b")
        corpus = lm_corpus(64 * 64 + 1, cfg.vocab_size)
        rows = pack_sequences(corpus, 32)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        ds = IDPADataset({"rows": rows}, num_nodes=2, batches=2,
                         frequencies=[1, 1])

        def loss_fn(p, b):
            return lm.loss_fn(p, host_batch(b["rows"]), cfg)

        tc = TrainConfig(outer_strategy="agwu", outer_nodes=2,
                         learning_rate=3e-3, warmup_steps=4,
                         total_steps=100, local_steps=3)
        tr = BPTTrainer(loss_fn, params, ds, tc, batch_size=16)
        rep = tr.train(rounds=5)
        assert rep.losses[-1] < rep.losses[0]


class TestServing:
    def test_greedy_generation(self):
        from repro.launch.serve import greedy_generate  # reprolint: disable=RPL401
        cfg = configs.get_reduced("hymba-1.5b")
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                                     cfg.vocab_size, dtype=jnp.int32)
        out = greedy_generate(params, cfg, prompts, max_seq=16, gen=4)  # reprolint: disable=RPL401
        assert out.shape == (2, 4)
        assert int(out.min()) >= 0 and int(out.max()) < cfg.vocab_size
