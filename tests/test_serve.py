"""Serving-subsystem suite: one-call prefill equivalence, slot-cache
invariants, the serve-side single-decision-point guarantee, the
greedy_generate deprecation shim, engine streaming semantics under the
deterministic cost clock, and a smoke test of the rebuilt CLI.

Mirrors test_engine.py's structure: linter-enforced config hygiene
(reprolint rules RPL102/RPL402) plus behavioural contracts over the
streaming event API.
"""
import os
import subprocess
import sys
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.serving.engine as serve_engine_module
from repro.core.types import ModelConfig
from repro.models import lm
from repro.serving import (ContinuousServeEngine, Request, ServeConfig,
                           SlotAllocator, StaticServeEngine,
                           make_serve_engine, poisson_requests,
                           resolve_serve_engine)

REPO = Path(__file__).resolve().parents[1]


def _tiny(arch_type, **kw):
    base = dict(name="t", arch_type=arch_type, num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=128)
    base.update(kw)
    return ModelConfig(**base)


ARCH_CFGS = {
    "dense": _tiny("dense"),
    "windowed": _tiny("dense", sliding_window=8, window_pattern=2),
    "moe": _tiny("moe", num_experts=4, top_k=2, expert_d_ff=64,
                 moe_capacity_factor=8.0),
    "ssm": _tiny("ssm", num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0,
                 ssm_heads=4, ssm_head_dim=16, ssm_state=8),
    "hybrid": _tiny("hybrid", ssm_heads=4, ssm_head_dim=16, ssm_state=8),
}

# ulp-scale tolerances: prefill computes the same values as the decode
# loop but through differently-fused matmuls, so bf16 cache payloads may
# differ by a couple of ulps and the f32 SSM state by the chunked-vs-
# sequential recurrence reordering
CACHE_ATOL = {"k": 0.08, "v": 0.08, "conv": 0.08, "ssm": 5e-3}


@pytest.fixture(scope="module")
def dense_setup():
    cfg = ARCH_CFGS["dense"]
    return cfg, lm.init_params(jax.random.PRNGKey(0), cfg)


# ----------------------------------------------------------------------
# one-call prefill == token-by-token prefill
# ----------------------------------------------------------------------
class TestPrefillEquivalence:
    @pytest.mark.parametrize("arch", list(ARCH_CFGS), ids=list(ARCH_CFGS))
    def test_prefill_matches_decode_loop(self, arch):
        """lm.prefill (ONE forward with collect_cache) must reproduce the
        cache and last logits of P sequential decode_step calls — for
        every arch family, leaf by leaf."""
        cfg = ARCH_CFGS[arch]
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        P = 12
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, P), 0,
                                  cfg.vocab_size)
        logits1, sl = lm.prefill(params, toks, cfg)
        assert logits1.shape == (2, 1, cfg.vocab_size)
        assert list(np.asarray(sl.lengths)) == [P, P]

        cache = lm.init_cache(2, P + 4, cfg)
        logits2 = None
        for i in range(P):
            logits2, cache = lm.decode_step(params, cache, jnp.int32(i),
                                            toks[:, i:i + 1], cfg)
        np.testing.assert_allclose(np.asarray(logits1),
                                   np.asarray(logits2), atol=0.05)

        def check(path, a, b):
            leaf = path[-1].key
            a = jnp.asarray(a, jnp.float32)
            b = jnp.asarray(b, jnp.float32)
            if a.shape != b.shape:          # kv slice is seq-trimmed to P
                b = b[:, :, :a.shape[2]]
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=CACHE_ATOL[leaf],
                                       err_msg=f"cache leaf {leaf}")
        jax.tree_util.tree_map_with_path(check, sl.layers, cache.layers)

    def test_prefill_is_one_jitted_call(self, dense_setup):
        """Trace-count proof: the engine's prefill traces ONCE per prompt
        shape, no matter how many prompts of that shape it serves."""
        cfg, params = dense_setup
        eng = make_serve_engine(params, cfg, ServeConfig(slots=2, max_seq=64))
        for seed in range(3):
            toks = jax.random.randint(jax.random.PRNGKey(seed), (1, 10), 0,
                                      cfg.vocab_size)
            eng.prefill(toks)
        assert eng.prefill_traces == 1
        eng.prefill(jnp.zeros((1, 7), jnp.int32))    # new shape: one more
        assert eng.prefill_traces == 2

    def test_decode_traces_once(self, dense_setup):
        cfg, params = dense_setup
        eng = make_serve_engine(params, cfg, ServeConfig(slots=2, max_seq=32))
        _, sl, _ = eng.prefill(jnp.zeros((1, 4), jnp.int32))
        eng.insert(sl, 0)
        for _ in range(4):
            eng.decode(np.zeros((2,), np.int32))
        assert eng.decode_traces == 1


# ----------------------------------------------------------------------
# slot invariants
# ----------------------------------------------------------------------
class TestSlotInvariants:
    def test_insert_evict_lengths(self, dense_setup):
        cfg, params = dense_setup
        cache = lm.init_cache(4, 32, cfg)
        _, sl = lm.prefill(params, jnp.zeros((1, 5), jnp.int32), cfg)
        cache = lm.cache_insert(cache, sl, 2)
        assert list(np.asarray(cache.lengths)) == [0, 0, 5, 0]
        cache = lm.cache_evict(cache, 2)
        assert list(np.asarray(cache.lengths)) == [0, 0, 0, 0]

    def test_auto_increment_only_occupied(self, dense_setup):
        cfg, params = dense_setup
        cache = lm.init_cache(4, 32, cfg)
        _, sl = lm.prefill(params, jnp.zeros((1, 5), jnp.int32), cfg)
        cache = lm.cache_insert(cache, sl, 1)
        _, cache = lm.decode_step(params, cache, None,
                                  jnp.zeros((4, 1), jnp.int32), cfg)
        assert list(np.asarray(cache.lengths)) == [0, 6, 0, 0]

    def test_evicted_slot_reusable_without_interference(self, dense_setup):
        """Evict slot s, insert a NEW request into s: a resident slot's
        next-token logits must be BIT-IDENTICAL to a run where s stayed
        empty — the lengths mask makes stale payload unreachable."""
        cfg, params = dense_setup
        t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 7), 0,
                                cfg.vocab_size)
        t2 = jax.random.randint(jax.random.PRNGKey(2), (1, 11), 0,
                                cfg.vocab_size)
        _, s1 = lm.prefill(params, t1, cfg)
        _, s2 = lm.prefill(params, t2, cfg)
        base = lm.init_cache(4, 32, cfg)
        base = lm.cache_insert(base, s1, 0)
        occupied = lm.cache_insert(base, s2, 2)      # resident neighbour
        occupied = lm.cache_evict(occupied, 2)       # ... then evicted
        reused = lm.cache_insert(occupied, s2, 2)    # slot 2 reused
        toks = jnp.zeros((4, 1), jnp.int32)
        la, _ = lm.decode_step(params, occupied, None, toks, cfg)
        lb, _ = lm.decode_step(params, reused, None, toks, cfg)
        assert jnp.array_equal(la[0], lb[0]), \
            "slot-2 payload leaked into slot 0's decode"

    def test_slot_decode_matches_standalone(self, dense_setup):
        """A slot decoding inside a shared cache must match the same
        request served alone in a batch-1 cache."""
        cfg, params = dense_setup
        t = jax.random.randint(jax.random.PRNGKey(3), (1, 9), 0,
                               cfg.vocab_size)
        lg, sl = lm.prefill(params, t, cfg)
        nxt = jnp.argmax(lg[:, 0], -1).astype(jnp.int32)
        big = lm.cache_insert(lm.init_cache(4, 32, cfg), sl, 3)
        toks = jnp.zeros((4, 1), jnp.int32).at[3, 0].set(nxt[0])
        l_shared, _ = lm.decode_step(params, big, None, toks, cfg)
        solo = lm.cache_insert(lm.init_cache(1, 32, cfg), sl, 0)
        l_solo, _ = lm.decode_step(params, solo, None, nxt[:, None], cfg)
        np.testing.assert_allclose(np.asarray(l_shared[3]),
                                   np.asarray(l_solo[0]), atol=0.05)

    def test_allocator(self):
        al = SlotAllocator(2)
        assert al.alloc() == 0 and al.alloc() == 1
        with pytest.raises(RuntimeError, match="no free"):
            al.alloc()
        al.free(0)
        assert al.alloc() == 0                      # lowest slot reused
        with pytest.raises(ValueError):
            al.free(7)


# ----------------------------------------------------------------------
# config hygiene (linter-enforced, like test_engine.py)
# ----------------------------------------------------------------------
class TestSingleDecisionPoint:
    def test_only_resolve_serve_engine_reads_dispatch_fields(self):
        """No module under src/repro other than serving/engine.py reads
        the ServeConfig ``batching`` / ``timing`` dispatch fields off a
        config object.  Asserted through reprolint's AST pass (rule
        RPL102), the successor of the old raw-source regex — attribute
        reads match on the tree and the getattr spelling is caught."""
        from tools.reprolint import lint_paths
        root = Path(serve_engine_module.__file__).parents[1]   # src/repro
        offenders = [
            f"{Path(f.path).relative_to(root)}:{f.line}"
            for f in lint_paths([str(root)], only=["RPL102"])
        ]
        assert not offenders, (
            "ServeConfig dispatch fields must only be inspected by "
            f"resolve_serve_engine, found: {offenders}")

    def test_no_caller_uses_legacy_init_cache_order(self):
        """The cfg-first ``init_cache(cfg, batch, max_seq)`` order is
        shimmed but must not gain callers (rule RPL402; the deliberate
        shim exercise below carries an inline suppression)."""
        from tools.reprolint import lint_paths
        offenders = [
            f"{Path(f.path).relative_to(REPO)}:{f.line}"
            for f in lint_paths(
                [str(REPO / d) for d in ("src", "tests", "benchmarks")],
                only=["RPL402"])
        ]
        assert not offenders, \
            f"legacy init_cache(cfg, ...) call order found: {offenders}"

    def test_legacy_init_cache_order_warns_and_works(self, dense_setup):
        cfg, _ = dense_setup
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            old = getattr(  # noqa: B009  # reprolint: disable=RPL402
                lm, "init_cache")(cfg, 2, 16)
        assert any(issubclass(w.category, DeprecationWarning)
                   for w in caught)
        new = lm.init_cache(2, 16, cfg)
        assert jax.tree_util.tree_structure(old) == \
            jax.tree_util.tree_structure(new)


class TestResolve:
    def test_encdec_rejected(self):
        cfg = _tiny("encdec", num_encoder_layers=1, num_frontend_tokens=4)
        with pytest.raises(ValueError, match="encdec"):
            resolve_serve_engine(cfg, ServeConfig())

    def test_bad_config_values(self):
        with pytest.raises(ValueError, match="batching"):
            ServeConfig(batching="adaptive")
        with pytest.raises(ValueError, match="timing"):
            ServeConfig(timing="wall")
        with pytest.raises(ValueError, match="cache_dtype"):
            ServeConfig(cache_dtype="int8")
        with pytest.raises(ValueError, match="slots"):
            ServeConfig(slots=0)

    def test_dispatch(self):
        cfg = ARCH_CFGS["dense"]
        plan = resolve_serve_engine(cfg, ServeConfig(batching="continuous",
                                                     timing="model"))
        assert plan.engine_cls is ContinuousServeEngine
        assert plan.timer.source == "model"
        plan = resolve_serve_engine(cfg, ServeConfig(batching="static"))
        assert plan.engine_cls is StaticServeEngine
        assert plan.timer.source == "measured"

    def test_request_over_budget_rejected(self, dense_setup):
        cfg, params = dense_setup
        eng = make_serve_engine(params, cfg, ServeConfig(
            slots=2, max_seq=16, max_new_tokens=4, timing="model"))
        bad = [Request(id=0, arrival_ms=0.0,
                       tokens=np.zeros(14, np.int32))]   # 14 + 4 > 16
        with pytest.raises(ValueError, match="max_seq"):
            list(eng.run(bad))


# ----------------------------------------------------------------------
# deprecated greedy_generate shim
# ----------------------------------------------------------------------
class TestGreedyGenerateShim:
    def test_shim_warns_and_matches_engine(self, dense_setup):
        from repro.launch.serve import greedy_generate  # reprolint: disable=RPL401
        cfg, params = dense_setup
        prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 6), 0,
                                     cfg.vocab_size, dtype=jnp.int32)
        with pytest.warns(DeprecationWarning):
            shim_out = greedy_generate(params, cfg, prompts, max_seq=24,  # reprolint: disable=RPL401
                                       gen=5)
        eng = make_serve_engine(params, cfg, ServeConfig(slots=2,
                                                         max_seq=24))
        engine_out = eng.generate(prompts, 5)
        assert shim_out.shape == (2, 5)
        assert jnp.array_equal(shim_out, engine_out)


# ----------------------------------------------------------------------
# streaming semantics under the deterministic cost clock
# ----------------------------------------------------------------------
class TestStreaming:
    def _events(self, batching, reqs, cfg, params, **kw):
        eng = make_serve_engine(params, cfg, ServeConfig(
            slots=4, max_seq=96, timing="model", batching=batching, **kw))
        return list(eng.run(reqs))

    def test_lifecycle_and_continuous_beats_static(self, dense_setup):
        cfg, params = dense_setup
        reqs = poisson_requests(16, rate_rps=1000.0, seed=11,
                                vocab_size=cfg.vocab_size)
        per = {}
        for batching in ("continuous", "static"):
            evs = self._events(batching, reqs, cfg, params)
            comp = {e.request: e for e in evs if e.kind == "complete"}
            assert len(comp) == len(reqs)
            for r in reqs:
                mine = [e for e in evs if e.request == r.id]
                kinds = [e.kind for e in mine]
                assert kinds[0] == "arrival" and kinds[1] == "prefill" \
                    and kinds[-1] == "complete"
                ts = [e.t_ms for e in mine[1:]]       # clock monotone
                assert ts == sorted(ts)
                assert len(comp[r.id].tokens) == r.max_new_tokens
                assert comp[r.id].latency_ms >= comp[r.id].ttft_ms > 0
            per[batching] = max(e.t_ms for e in evs)
        # same virtual cost model, same stream: continuous finishes sooner
        assert per["continuous"] < per["static"]

    def test_engines_generate_identical_tokens(self, dense_setup):
        """Batching strategy must not change greedy outputs — only when
        tokens are produced."""
        cfg, params = dense_setup
        reqs = poisson_requests(10, rate_rps=500.0, seed=13,
                                vocab_size=cfg.vocab_size)
        tok = {}
        for batching in ("continuous", "static"):
            evs = self._events(batching, reqs, cfg, params)
            tok[batching] = {e.request: e.tokens for e in evs
                             if e.kind == "complete"}
        assert tok["continuous"] == tok["static"]

    def test_model_clock_deterministic(self, dense_setup):
        cfg, params = dense_setup
        reqs = poisson_requests(6, rate_rps=400.0, seed=2,
                                vocab_size=cfg.vocab_size)
        a = self._events("continuous", reqs, cfg, params)
        b = self._events("continuous", reqs, cfg, params)
        assert [(e.kind, e.request, e.t_ms, e.token) for e in a] == \
            [(e.kind, e.request, e.t_ms, e.token) for e in b]


# ----------------------------------------------------------------------
# compile budgets: prefill-compiles-per-prompt-length (bucketing sentinel)
# ----------------------------------------------------------------------
class TestCompileBudgets:
    """The serve engine's compile economics, pinned.

    Today the prefill jit retraces once per DISTINCT prompt length —
    the documented budget.  The ROADMAP prompt-length-bucketing item
    will cut this to one trace per bucket; when it lands, the
    documented-budget test starts failing (update the expected count)
    and the strict-xfail test starts XPASS-erroring — both fire, in
    opposite directions, so the sentinel cannot rot silently.
    """

    def test_prefill_compiles_once_per_prompt_length(self, dense_setup):
        cfg, params = dense_setup
        lens = (6, 10, 14)
        reqs = poisson_requests(12, rate_rps=800.0, seed=5,
                                prompt_lens=lens, gen_lens=(4,),
                                gen_probs=(1.0,),
                                vocab_size=cfg.vocab_size)
        eng = make_serve_engine(params, cfg, ServeConfig(
            slots=4, max_seq=64, timing="model", batching="continuous"))
        done = [e for e in eng.run(reqs) if e.kind == "complete"]
        assert len(done) == len(reqs)
        served = {len(r.tokens) for r in reqs}
        assert eng.prefill_traces == len(served), (
            f"prefill traced {eng.prefill_traces}x for {sorted(served)} — "
            "budget is one trace per distinct prompt length (pre-"
            "bucketing); if bucketing landed, update this budget")

    @pytest.mark.xfail(
        strict=True,
        reason="prompt-length bucketing not implemented: prefill "
               "retraces per distinct length (ROADMAP item); XPASS "
               "here means bucketing landed — delete the xfail")
    def test_prefill_bucketing_single_trace(self, dense_setup):
        cfg, params = dense_setup
        reqs = poisson_requests(8, rate_rps=800.0, seed=6,
                                prompt_lens=(6, 10, 14), gen_lens=(4,),
                                gen_probs=(1.0,),
                                vocab_size=cfg.vocab_size)
        eng = make_serve_engine(params, cfg, ServeConfig(
            slots=4, max_seq=64, timing="model", batching="continuous"))
        list(eng.run(reqs))
        assert eng.prefill_traces == 1

    def test_decode_steady_state_meets_zero_budget(self, dense_setup):
        """After warmup, the decode loop must dispatch from cache — the
        compile_budget(0) contract the benchmark harness also pins."""
        from repro.sanitize import compile_budget
        cfg, params = dense_setup
        eng = make_serve_engine(params, cfg, ServeConfig(slots=2,
                                                         max_seq=32))
        _, sl, _ = eng.prefill(jnp.zeros((1, 4), jnp.int32))
        eng.insert(sl, 0)
        eng.decode(np.zeros((2,), np.int32))       # warmup trace
        with compile_budget(0, what="traces", label="serve decode"):
            for _ in range(6):
                eng.decode(np.zeros((2,), np.int32))


# ----------------------------------------------------------------------
# CLI smoke
# ----------------------------------------------------------------------
class TestServeCLI:
    def test_cli_smoke(self):
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve",
             "--arch", "mamba2-370m", "--requests", "3", "--rate", "300",
             "--slots", "2", "--gen", "4", "--timing", "model"],
            cwd=REPO, capture_output=True, text=True, timeout=900,
            env={**os.environ, "PYTHONPATH": str(REPO / "src")})
        assert r.returncode == 0, r.stderr[-2000:]
        assert "3 requests" in r.stdout
        assert "p99" in r.stdout
