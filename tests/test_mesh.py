"""launch.mesh invariants: the MESHES table, builders and axis helpers.

Runs on any device count — entries that need more devices than the host
has assert the RuntimeError contract instead.
"""
import math

import jax
import pytest

from repro.launch.mesh import (MESHES, data_axes, make_hybrid_mesh,
                               make_mesh, make_nodes_mesh)

NDEV = len(jax.devices())


class TestMeshesTable:
    def test_shapes_match_axes(self):
        for name, (shape, axes) in MESHES.items():
            assert len(shape) == len(axes), name
            assert all(s >= 1 for s in shape), name
            assert len(set(axes)) == len(axes), name     # axes are unique

    def test_nodes_family(self):
        for m in (2, 4, 8, 16):
            shape, axes = MESHES[f"nodes{m}"]
            assert shape == (m,) and axes == ("nodes",)

    def test_hybrid_family(self):
        """Every nodesNxmodelK entry is (N, K) over ('nodes', 'model')."""
        hybrids = {n: v for n, v in MESHES.items()
                   if n.startswith("nodes") and "xmodel" in n}
        assert set(hybrids) >= {"nodes2xmodel2", "nodes4xmodel2",
                                "nodes2xmodel4", "nodes8xmodel2"}
        for name, (shape, axes) in hybrids.items():
            n, k = name.removeprefix("nodes").split("xmodel")
            assert shape == (int(n), int(k)), name
            assert axes == ("nodes", "model"), name

    def test_model_axis_present_where_expected(self):
        for name in ("pod", "multipod", "tiny", "tiny3d"):
            _, axes = MESHES[name]
            assert "model" in axes


class TestMakeMesh:
    def test_builds_when_devices_suffice(self):
        eligible = [n for n, (s, _) in MESHES.items()
                    if math.prod(s) <= NDEV]
        if not eligible:         # single-device tier-1 run
            pytest.skip("no MESHES entry fits this device count")
        for name in eligible:
            mesh = make_mesh(name)
            shape, axes = MESHES[name]
            assert mesh.axis_names == axes
            assert tuple(mesh.shape[a] for a in axes) == shape

    def test_insufficient_devices_raise(self):
        too_big = [n for n, (s, _) in MESHES.items()
                   if math.prod(s) > NDEV]
        for name in too_big:
            with pytest.raises(RuntimeError, match="devices"):
                make_mesh(name)

    def test_unknown_name_raises(self):
        with pytest.raises(KeyError):
            make_mesh("nope")


class TestHybridBuilder:
    def test_bad_counts(self):
        with pytest.raises(ValueError):
            make_hybrid_mesh(0, 2)
        with pytest.raises(ValueError):
            make_hybrid_mesh(2, 0)

    def test_too_few_devices(self):
        with pytest.raises(RuntimeError, match="hybrid mesh"):
            make_hybrid_mesh(NDEV + 1, 2)

    @pytest.mark.skipif(NDEV < 4, reason="needs 4 devices")
    def test_builds_2x2(self):
        mesh = make_hybrid_mesh(2, 2)
        assert mesh.axis_names == ("nodes", "model")
        assert dict(mesh.shape) == {"nodes": 2, "model": 2}

    @pytest.mark.skipif(NDEV < 2, reason="needs 2 devices")
    def test_named_entry_matches_builder(self):
        if NDEV < 4:
            pytest.skip("needs 4 devices")
        named = make_mesh("nodes2xmodel2")
        built = make_hybrid_mesh(2, 2)
        assert dict(named.shape) == dict(built.shape)
        assert named.axis_names == built.axis_names


class TestDataAxes:
    @pytest.mark.skipif(NDEV < 1, reason="needs a device")
    def test_nodes_mesh_has_no_data_axes(self):
        assert data_axes(make_nodes_mesh(1)) == ()

    @pytest.mark.skipif(NDEV < 4, reason="needs 4 devices")
    def test_tiny_mesh(self):
        assert data_axes(make_mesh("tiny")) == ("data",)

    @pytest.mark.skipif(NDEV < 8, reason="needs 8 devices")
    def test_tiny3d_mesh(self):
        assert data_axes(make_mesh("tiny3d")) == ("pod", "data")
