"""Runtime-sanitizer suite (repro.sanitize): transfer-guard semantics,
the sanctioned escape hatch and its audit log, compile budgets, and the
engines running end-to-end under ``REPRO_SANITIZE=1``.

The transfer tests exercise the implicit HOST-TO-DEVICE class (numpy
leaves reaching a jit dispatch), which is the class the CPU backend can
enforce — device arrays are host-resident on CPU, so the d2h half of
the guard only arms on real accelerators (see the harness docstring).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sanitize import (CompileBudgetExceeded, clear_sync_log,
                            compile_budget, compile_counts,
                            install_compile_listener, sanctioned_scope,
                            sanctioned_sync, sanitize_enabled, sanitized,
                            sync_log)


@pytest.fixture
def sanitize_on(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    clear_sync_log()
    yield
    clear_sync_log()


# ----------------------------------------------------------------------
# gating
# ----------------------------------------------------------------------
class TestGating:
    @pytest.mark.parametrize("val,on", [
        ("1", True), ("on", True), ("yes", True),
        ("", False), ("0", False), ("off", False), ("OFF", False),
    ])
    def test_env_values(self, monkeypatch, val, on):
        monkeypatch.setenv("REPRO_SANITIZE", val)
        assert sanitize_enabled() is on

    def test_unset_is_off(self, monkeypatch):
        monkeypatch.delenv("REPRO_SANITIZE", raising=False)
        assert not sanitize_enabled()

    def test_sanitized_is_noop_when_off(self, monkeypatch):
        monkeypatch.setenv("REPRO_SANITIZE", "0")
        with sanitized("noop"):
            # implicit h2d: numpy leaves straight into a jitted add —
            # legal because the guard never armed
            out = jax.jit(lambda a, b: a + b)(np.ones(3), np.ones(3))
        assert float(out.sum()) == 6.0


# ----------------------------------------------------------------------
# transfer guards
# ----------------------------------------------------------------------
class TestTransferGuard:
    def test_implicit_h2d_raises_inside_sanitized(self, sanitize_on):
        host = np.ones((4,), np.float32)
        with pytest.raises(Exception, match="[Dd]isallow"):
            with sanitized("test"):
                jnp.stack([host, host])

    def test_jit_dispatch_of_numpy_raises(self, sanitize_on):
        host = np.ones((5,), np.float32)
        f = jax.jit(lambda a: a * 2)
        with pytest.raises(Exception, match="[Dd]isallow"):
            with sanitized("test"):
                f(host)

    def test_explicit_device_put_is_legal(self, sanitize_on):
        host = {"w": np.ones((6,), np.float32)}
        with sanitized("test"):
            dev = jax.device_put(host)
            out = jax.jit(lambda t: t["w"] + 1)(dev)
        assert out.shape == (6,)

    def test_sanctioned_scope_allows_and_logs(self, sanitize_on):
        host = np.ones((7,), np.float32)
        with sanitized("test"):
            with sanctioned_scope("deliberate-upload"):
                dev = jnp.stack([host, host])
        assert dev.shape == (2, 7)
        assert sync_log() == ["deliberate-upload"]

    def test_sanctioned_sync_pulls_and_logs(self, sanitize_on):
        x = {"a": jnp.arange(3.0), "b": jnp.ones((2, 2))}
        with sanitized("test"):
            out = sanctioned_sync(x, "round.losses")
        assert isinstance(out["a"], np.ndarray)
        assert isinstance(out["b"], np.ndarray)
        np.testing.assert_array_equal(out["a"], [0.0, 1.0, 2.0])
        assert sync_log() == ["round.losses"]

    def test_sanctioned_sync_works_with_gate_off(self, monkeypatch):
        monkeypatch.setenv("REPRO_SANITIZE", "0")
        out = sanctioned_sync(jnp.ones(3), "plain")
        assert isinstance(out, np.ndarray)


# ----------------------------------------------------------------------
# compile budgets
# ----------------------------------------------------------------------
class TestCompileBudget:
    def test_fresh_compile_busts_zero_budget(self):
        # unique shape so no earlier test can have warmed this dispatch
        x = jnp.ones((3, 131))
        f = jax.jit(lambda a: (a * 2).sum(axis=1))
        with pytest.raises(CompileBudgetExceeded, match="budget"):
            with compile_budget(0, label="cold path"):
                f(x)

    def test_warmed_path_meets_zero_budget(self):
        x = jnp.ones((3, 137))
        f = jax.jit(lambda a: (a * 3).sum(axis=1))
        f(x)                                   # warmup compile
        with compile_budget(0, label="steady state"):
            for _ in range(4):
                f(x)

    def test_shape_drift_is_caught(self):
        f = jax.jit(lambda a: a + 1)
        f(jnp.ones((2, 139)))
        with pytest.raises(CompileBudgetExceeded):
            with compile_budget(0, what="traces", label="drift"):
                f(jnp.ones((4, 139)))          # new shape -> retrace

    def test_nonzero_budget_allows_bounded_compiles(self):
        f = jax.jit(lambda a: a - 1)
        # one fresh compilation emits a handful of trace/compile events;
        # a generous upper bound documents "at most one compilation"
        with compile_budget(8, what="traces", label="one warmup"):
            f(jnp.ones((2, 149)))

    def test_counters_are_monotonic_and_listener_idempotent(self):
        install_compile_listener()
        install_compile_listener()             # second install: no-op
        before = compile_counts()
        jax.jit(lambda a: a * 5)(jnp.ones((2, 151)))
        after = compile_counts()
        assert after["traces"] > before["traces"]
        assert after["compiles"] >= before["compiles"]


# ----------------------------------------------------------------------
# engines under the sanitizer: the CI REPRO_SANITIZE=1 leg in miniature
# ----------------------------------------------------------------------
class TestEngineUnderSanitizer:
    def _trainer(self, strategy, m=2, eval_fn=False, **tc_kwargs):
        from repro.core.bpt_trainer import BPTTrainer
        from repro.core.types import TrainConfig
        from repro.data.pipeline import IDPADataset
        from repro.data.synthetic import image_dataset
        from repro.models.cnn import (CNNConfig, cnn_accuracy, cnn_loss,
                                      init_cnn)
        cfg = CNNConfig(name="san", image_size=8, conv_layers=1, filters=4,
                        fc_layers=1, fc_neurons=32)
        xs, ys = image_dataset(64 * m * 2, size=8, seed=0)
        params = init_cnn(jax.random.PRNGKey(0), cfg)
        ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=m,
                         batches=1)
        tc = TrainConfig(outer_strategy=strategy, outer_nodes=m,
                         optimizer="adamw", learning_rate=2e-3,
                         total_steps=100, warmup_steps=5, local_steps=2,
                         seed=0, **tc_kwargs)
        ef = None
        if eval_fn:
            xe, ye = image_dataset(32, size=8, seed=9)
            eb = {"images": jnp.asarray(xe), "labels": jnp.asarray(ye)}
            ef = jax.jit(lambda p: cnn_accuracy(p, eb, cfg))
        return BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}),
                          params, ds, tc, batch_size=16, eval_fn=ef)

    @pytest.mark.parametrize("strategy", ["sgwu", "agwu"])
    def test_round_bodies_run_clean_under_guard(self, sanitize_on,
                                                strategy):
        """Zero unsanctioned transfers in the engine round bodies: the
        whole train loop completes with the guard armed, and the only
        host pulls are the logged sanctioned ones."""
        rep = self._trainer(strategy, eval_fn=True).train(rounds=2)
        assert len(rep.losses) >= 2
        assert all(np.isfinite(loss) for loss in rep.losses)
        labels = set(sync_log())
        # the Eq. 8 measurement boundary must be among the sanctioned
        # syncs — it is a *sanctioned* host sync, not an eliminated one
        assert any("loss" in lbl for lbl in labels), labels

    def test_sequential_engine_under_guard(self, sanitize_on):
        rep = self._trainer("sgwu", fused_outer=False).train(rounds=2)
        assert len(rep.losses) >= 2

    def test_scan_engine_under_guard(self, sanitize_on):
        rep = self._trainer("sync").train(rounds=2)
        assert len(rep.losses) >= 2
