"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see ONE device;
only launch/dryrun.py (run as a subprocess) forces 512 placeholder devices.
"""
try:
    import hypothesis  # noqa: F401 — real install (the `test` extra) wins
except ImportError:
    from repro.testing.hypothesis_fallback import install as _install_hyp
    _install_hyp()

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def assert_no_nans(tree):
    import jax.numpy as jnp
    for leaf in jax.tree_util.tree_leaves(tree):
        assert not bool(jnp.isnan(leaf).any()), "NaN leaf"
