"""Dry-run machinery tests (subprocess: needs forced multi-device jax).

Runs ``python -m repro.launch.dryrun`` on the tiny 2x2 and 2x2x2 meshes for
representative archs — proving lower+compile+analysis works for every
arch family and both mesh topologies — plus roofline parser unit tests
that need no devices.
"""
import json
import os
import subprocess
import sys

import pytest

from repro import configs
from repro.launch import roofline

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def run_dryrun(*args, timeout=900):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        cwd=REPO, env=ENV, capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
class TestDryrunSubprocess:
    @pytest.mark.parametrize("arch,shape", [
        ("mamba2-370m", "train_4k"),          # ssm
        ("granite-moe-3b-a800m", "decode_32k"),  # moe + expert parallel
        ("seamless-m4t-large-v2", "train_4k"),   # enc-dec
    ])
    def test_tiny_mesh(self, arch, shape):
        r = run_dryrun("--arch", arch, "--shape", shape, "--mesh", "tiny",
                       "--no-calibrate", "--tag", "test")
        assert r.returncode == 0, r.stderr[-2000:]
        fn = os.path.join(REPO, "experiments/dryrun",
                          f"{arch}__{shape}__tiny__test.json")
        assert os.path.exists(fn)
        data = json.load(open(fn))
        assert data["chips"] == 4
        assert data["memory_analysis"]["temp_size_in_bytes"] > 0

    def test_multipod_tiny3d(self):
        """The 'pod' axis shards: 3-level mesh lowers and compiles."""
        r = run_dryrun("--arch", "hymba-1.5b", "--shape", "long_500k",
                       "--mesh", "tiny3d", "--no-calibrate", "--tag", "test")
        assert r.returncode == 0, r.stderr[-2000:]
        fn = os.path.join(REPO, "experiments/dryrun",
                          "hymba-1.5b__long_500k__tiny3d__test.json")
        data = json.load(open(fn))
        assert data["chips"] == 8

    def test_calibration_path(self):
        r = run_dryrun("--arch", "mamba2-370m", "--shape", "decode_32k",
                       "--mesh", "tiny")
        assert r.returncode == 0, r.stderr[-2000:]
        fn = os.path.join(REPO, "experiments/dryrun",
                          "mamba2-370m__decode_32k__tiny.json")
        data = json.load(open(fn))
        assert "roofline" in data
        r = data["roofline"]
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert data["calibrated"]["flops"] > 0


class TestRooflineParser:
    HLO = """
  %ag = f32[16,4096]{1,0} all-gather(f32[1,4096]{1,0} %x), dimensions={0}
  %ar.1 = bf16[256,128]{1,0} all-reduce(bf16[256,128]{1,0} %y), to_apply=%add
  %aa = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
  %cp = u8[1024]{0} collective-permute(u8[1024]{0} %z), source_target_pairs={{0,1}}
  %ars = f32[4]{0} all-reduce-start(f32[4]{0} %w), to_apply=%add
  %ard = f32[4]{0} all-reduce-done(f32[4]{0} %ars)
"""

    def test_parse_kinds_and_bytes(self):
        d = roofline.parse_hlo_collectives(self.HLO)
        assert d["all-gather"] == 16 * 4096 * 4
        assert d["all-reduce"] == 256 * 128 * 2 + 4 * 4   # sync + start only
        assert d["all-to-all"] == 2 * 8 * 8 * 4
        assert d["collective-permute"] == 1024
        assert d["_counts"]["all-reduce"] == 2

    def test_shape_bytes_tuple(self):
        assert roofline._shape_bytes("(f32[2,3]{1,0}, bf16[4]{0})") == \
            2 * 3 * 4 + 4 * 2

    def test_model_flops(self):
        cfg = configs.get_config("yi-6b")
        shape = configs.get_shape("train_4k")
        mf = roofline.model_flops(cfg, shape)
        # 6 * 6.06e9 * (256*4096) ~ 3.8e16
        assert 3.5e16 < mf < 4.2e16

    def test_terms_and_bottleneck(self):
        rep = roofline.RooflineReport(
            arch="x", shape="y", mesh="pod", chips=256,
            hlo_flops=1e15, hlo_bytes=1e12, coll_bytes=1e13,
            coll_detail={}, model_flops_=5e14, per_device_hbm=1e9)
        t = rep.terms()
        assert t["bottleneck"] == "collective"
        assert t["useful_flop_frac"] == pytest.approx(0.5)


class TestSkipsPolicy:
    def test_long_500k_skips_documented(self):
        for arch in configs.ARCH_NAMES:
            skipped = (arch, "long_500k") in configs.SKIPS
            native = arch in configs.LONG_CONTEXT_OK
            assert skipped != native      # exactly one holds

    def test_pairs_count(self):
        # 10 archs x 4 shapes - 7 documented long_500k skips = 33
        assert len(configs.pairs()) == 33
