"""Per-kernel allclose sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref
from repro.kernels.conv2d import conv2d_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestConv2d:
    @pytest.mark.parametrize("B,H,W,Cin,Cout,k", [
        (1, 8, 8, 1, 4, 3),
        (2, 16, 16, 3, 8, 3),
        (2, 12, 12, 4, 16, 5),
        (1, 7, 9, 2, 4, 3),          # odd spatial
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, H, W, Cin, Cout, k, dtype):
        key = jax.random.PRNGKey(hash((B, H, W, Cin, Cout, k)) % 2**31)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (B, H, W, Cin), dtype)
        w = rand(k2, (k, k, Cin, Cout), dtype)
        got = conv2d_pallas(x, w)
        want = ref.conv2d_ref(x, w)
        np.testing.assert_allclose(
            got.astype(jnp.float32), want.astype(jnp.float32),
            atol=TOL[dtype] * k * k * Cin, rtol=1e-2)

    def test_oc_tiling(self):
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (1, 8, 8, 3), jnp.float32)
        w = rand(k2, (3, 3, 3, 8), jnp.float32)
        a = conv2d_pallas(x, w, oc_tile=4)
        b = conv2d_pallas(x, w, oc_tile=8)
        np.testing.assert_allclose(a, b, atol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,KH,D", [
        (1, 64, 4, 4, 16),           # MHA
        (2, 100, 8, 2, 32),          # GQA, ragged seq
        (1, 128, 4, 1, 64),          # MQA
    ])
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, S, H, KH, D, causal, dtype):
        key = jax.random.PRNGKey(hash((B, S, H, KH, D, causal)) % 2**31)
        ks = jax.random.split(key, 3)
        q = rand(ks[0], (B, H, S, D), dtype)
        k = rand(ks[1], (B, KH, S, D), dtype)
        v = rand(ks[2], (B, KH, S, D), dtype)
        got = flash_attention_pallas(q, k, v, causal=causal,
                                     q_tile=32, k_tile=32)
        want = ref.attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(got.astype(jnp.float32),
                                   want.astype(jnp.float32),
                                   atol=TOL[dtype] * 4, rtol=2e-2)

    @pytest.mark.parametrize("window,softcap", [(16, 0.0), (0, 30.0),
                                                (8, 50.0)])
    def test_window_and_softcap(self, window, softcap):
        key = jax.random.PRNGKey(7)
        ks = jax.random.split(key, 3)
        q = rand(ks[0], (1, 4, 96, 32), jnp.float32)
        k = rand(ks[1], (1, 2, 96, 32), jnp.float32)
        v = rand(ks[2], (1, 2, 96, 32), jnp.float32)
        got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                     softcap=softcap, q_tile=32, k_tile=32)
        want = ref.attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=window or None,
            softcap=softcap).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)

    def test_ops_wrapper_layouts(self):
        """ops.flash_attention takes BSHD like the models."""
        key = jax.random.PRNGKey(3)
        ks = jax.random.split(key, 3)
        q = rand(ks[0], (2, 64, 4, 16), jnp.float32)
        k = rand(ks[1], (2, 64, 2, 16), jnp.float32)
        v = rand(ks[2], (2, 64, 2, 16), jnp.float32)
        got = ops.flash_attention(q, k, v, impl="pallas")
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)


class TestRMSNorm:
    @settings(max_examples=20, deadline=None)
    @given(rows=st.integers(1, 300), d=st.sampled_from([8, 64, 128, 512]),
           seed=st.integers(0, 99))
    def test_matches_ref_hypothesis(self, rows, d, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (rows, d))
        s = jax.random.normal(k2, (d,)) * 0.1 + 1.0
        got = rmsnorm_pallas(x, s, row_tile=64)
        want = ref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)

    @pytest.mark.parametrize("shape", [(5, 7, 64), (2, 3, 4, 32), (128,)])
    def test_nd_shapes(self, shape):
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, shape)
        s = jnp.ones((shape[-1],))
        got = rmsnorm_pallas(x, s)
        want = ref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


class TestOpsSelection:
    def test_default_on_cpu_is_ref(self):
        assert ops.default_impl() in ("ref", "pallas")

    def test_conv_grad_via_ref(self):
        """The ref conv path is differentiable (used by CNN training)."""
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (1, 8, 8, 2))
        w = jax.random.normal(k2, (3, 3, 2, 4))
        g = jax.grad(lambda w_: ops.conv2d(x, w_, impl="ref").sum())(w)
        assert g.shape == w.shape and float(jnp.abs(g).sum()) > 0
