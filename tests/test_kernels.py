"""Per-kernel allclose sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref
from repro.kernels.conv2d import conv2d_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestConv2d:
    @pytest.mark.parametrize("B,H,W,Cin,Cout,k", [
        (1, 8, 8, 1, 4, 3),
        (2, 16, 16, 3, 8, 3),
        (2, 12, 12, 4, 16, 5),
        (1, 7, 9, 2, 4, 3),          # odd spatial
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, H, W, Cin, Cout, k, dtype):
        key = jax.random.PRNGKey(hash((B, H, W, Cin, Cout, k)) % 2**31)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (B, H, W, Cin), dtype)
        w = rand(k2, (k, k, Cin, Cout), dtype)
        got = conv2d_pallas(x, w)
        want = ref.conv2d_ref(x, w)
        np.testing.assert_allclose(
            got.astype(jnp.float32), want.astype(jnp.float32),
            atol=TOL[dtype] * k * k * Cin, rtol=1e-2)

    def test_oc_tiling(self):
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (1, 8, 8, 3), jnp.float32)
        w = rand(k2, (3, 3, 3, 8), jnp.float32)
        a = conv2d_pallas(x, w, oc_tile=4)
        b = conv2d_pallas(x, w, oc_tile=8)
        np.testing.assert_allclose(a, b, atol=1e-5)

    @pytest.mark.parametrize("activation", ["none", "relu"])
    def test_fused_bias_activation_epilogue(self, activation):
        """Eq. (1)+(2) in one pallas_call matches conv -> +b -> act."""
        key = jax.random.PRNGKey(11)
        k1, k2, k3 = jax.random.split(key, 3)
        x = rand(k1, (2, 8, 8, 3), jnp.float32)
        w = rand(k2, (3, 3, 3, 8), jnp.float32)
        b = rand(k3, (8,), jnp.float32)
        got = conv2d_pallas(x, w, b, activation=activation)
        want = ref.conv2d_ref(x, w) + b
        if activation == "relu":
            want = jax.nn.relu(want)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def _lax_conv(x, w, padding):
    """The lax.conv_general_dilated oracle the gradient checks gate on."""
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))


class TestConv2dGrad:
    """jax.grad through the Pallas custom_vjp vs the lax.conv reference."""

    GRID = [
        # seed, padding, oc_tile, k, shape (B, H, W, Cin, Cout)
        (0, "SAME", 0, 3, (2, 8, 8, 3, 8)),
        (1, "SAME", 4, 3, (2, 8, 8, 3, 8)),
        (2, "VALID", 0, 3, (2, 8, 8, 3, 8)),
        (3, "VALID", 4, 3, (2, 8, 8, 3, 8)),
        (4, "SAME", 0, 5, (1, 9, 7, 2, 4)),     # odd kernel, odd spatial
        (5, "VALID", 2, 5, (1, 9, 7, 2, 4)),
        (6, "SAME", 0, 1, (2, 6, 6, 4, 4)),     # 1x1 conv
        (7, "SAME", 0, 2, (2, 8, 8, 3, 8)),     # even k: asymmetric pads
        (8, "SAME", 4, 4, (1, 8, 8, 2, 8)),     # even k, tiled
    ]

    @pytest.mark.parametrize("seed,padding,oc_tile,k,shape", GRID)
    @pytest.mark.parametrize("activation", ["none", "relu"])
    def test_grads_match_lax(self, seed, padding, oc_tile, k, shape,
                             activation):
        B, H, W, Cin, Cout = shape
        key = jax.random.PRNGKey(seed)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        x = rand(k1, (B, H, W, Cin), jnp.float32)
        w = rand(k2, (k, k, Cin, Cout), jnp.float32)
        b = rand(k3, (Cout,), jnp.float32)

        def loss_ref(x_, w_, b_):
            out = _lax_conv(x_, w_, padding) + b_
            if activation == "relu":
                out = jax.nn.relu(out)
            return jnp.sum(out * cot)

        def loss_pallas(x_, w_, b_):
            out = conv2d_pallas(x_, w_, b_, padding=padding,
                                activation=activation, oc_tile=oc_tile)
            return jnp.sum(out * cot)

        out_shape = jax.eval_shape(lambda a, c: _lax_conv(a, c, padding),
                                   x, w).shape
        cot = rand(k4, out_shape, jnp.float32)   # non-uniform cotangent
        got = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
        want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
        for g, r, name in zip(got, want, ("dx", "dw", "db"), strict=True):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                       atol=1e-4, rtol=1e-4,
                                       err_msg=f"{name} mismatch")

    def test_dw_batch_tiled_accumulation(self):
        """B=16 runs the dw kernel's sequential batch-tile grid (bt=8)."""
        key = jax.random.PRNGKey(12)
        k1, k2, k3 = jax.random.split(key, 3)
        x = rand(k1, (16, 8, 8, 3), jnp.float32)
        w = rand(k2, (3, 3, 3, 8), jnp.float32)
        cot = rand(k3, (16, 8, 8, 8), jnp.float32)
        got = jax.grad(lambda w_: jnp.sum(
            conv2d_pallas(x, w_, oc_tile=4) * cot))(w)
        want = jax.grad(lambda w_: jnp.sum(
            _lax_conv(x, w_, "SAME") * cot))(w)
        np.testing.assert_allclose(got, want, atol=5e-4, rtol=1e-4)

    def test_db_keeps_bias_dtype_mixed_precision(self):
        """bf16 activations with a float32 master bias -> float32 db."""
        key = jax.random.PRNGKey(14)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (2, 8, 8, 2), jnp.bfloat16)
        w = rand(k2, (3, 3, 2, 4), jnp.bfloat16)
        b = jnp.zeros((4,), jnp.float32)
        db = jax.grad(lambda b_: jnp.sum(
            conv2d_pallas(x, w, b_).astype(jnp.float32)))(b)
        assert db.dtype == jnp.float32

    def test_dw_odd_batch(self):
        """Odd B exercises the gcd batch-tile fallback (bt=1)."""
        key = jax.random.PRNGKey(15)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (5, 8, 8, 2), jnp.float32)
        w = rand(k2, (3, 3, 2, 4), jnp.float32)
        got = jax.grad(lambda w_: jnp.sum(conv2d_pallas(x, w_) ** 2))(w)
        want = jax.grad(lambda w_: jnp.sum(_lax_conv(x, w_, "SAME") ** 2))(w)
        np.testing.assert_allclose(got, want, atol=5e-4, rtol=1e-4)

    def test_non_divisor_oc_tile_raises(self):
        key = jax.random.PRNGKey(13)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (1, 8, 8, 2), jnp.float32)
        w = rand(k2, (3, 3, 2, 8), jnp.float32)
        with pytest.raises(ValueError):
            conv2d_pallas(x, w, oc_tile=3)

    def test_forward_matches_lax(self):
        key = jax.random.PRNGKey(5)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (2, 10, 10, 3), jnp.float32)
        w = rand(k2, (3, 3, 3, 8), jnp.float32)
        for padding in ("SAME", "VALID"):
            got = conv2d_pallas(x, w, padding=padding)
            np.testing.assert_allclose(got, _lax_conv(x, w, padding),
                                       atol=1e-5, rtol=1e-5)

    def test_no_bias_grad(self):
        """b=None still differentiates wrt x and w."""
        key = jax.random.PRNGKey(6)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (1, 8, 8, 2), jnp.float32)
        w = rand(k2, (3, 3, 2, 4), jnp.float32)
        got = jax.grad(lambda w_: jnp.sum(conv2d_pallas(x, w_) ** 2))(w)
        want = jax.grad(lambda w_: jnp.sum(_lax_conv(x, w_, "SAME") ** 2))(w)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_grad_under_jit_and_vmap(self):
        """The fused trainer wraps the conv in jit(vmap(grad(...)))."""
        key = jax.random.PRNGKey(7)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (3, 2, 8, 8, 2), jnp.float32)       # (m, B, H, W, C)
        w = rand(k2, (3, 3, 2, 4), jnp.float32)

        def loss(x_):
            return jnp.sum(conv2d_pallas(x_, w, activation="relu"))

        got = jax.jit(jax.vmap(jax.grad(loss)))(x)
        want = jax.vmap(jax.grad(
            lambda x_: jnp.sum(jax.nn.relu(_lax_conv(x_, w, "SAME")))))(x)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,KH,D", [
        (1, 64, 4, 4, 16),           # MHA
        (2, 100, 8, 2, 32),          # GQA, ragged seq
        (1, 128, 4, 1, 64),          # MQA
    ])
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, B, S, H, KH, D, causal, dtype):
        key = jax.random.PRNGKey(hash((B, S, H, KH, D, causal)) % 2**31)
        ks = jax.random.split(key, 3)
        q = rand(ks[0], (B, H, S, D), dtype)
        k = rand(ks[1], (B, KH, S, D), dtype)
        v = rand(ks[2], (B, KH, S, D), dtype)
        got = flash_attention_pallas(q, k, v, causal=causal,
                                     q_tile=32, k_tile=32)
        want = ref.attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(got.astype(jnp.float32),
                                   want.astype(jnp.float32),
                                   atol=TOL[dtype] * 4, rtol=2e-2)

    @pytest.mark.parametrize("window,softcap", [(16, 0.0), (0, 30.0),
                                                (8, 50.0)])
    def test_window_and_softcap(self, window, softcap):
        key = jax.random.PRNGKey(7)
        ks = jax.random.split(key, 3)
        q = rand(ks[0], (1, 4, 96, 32), jnp.float32)
        k = rand(ks[1], (1, 2, 96, 32), jnp.float32)
        v = rand(ks[2], (1, 2, 96, 32), jnp.float32)
        got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                     softcap=softcap, q_tile=32, k_tile=32)
        want = ref.attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=window or None,
            softcap=softcap).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)

    def test_ops_wrapper_layouts(self):
        """ops.flash_attention takes BSHD like the models."""
        key = jax.random.PRNGKey(3)
        ks = jax.random.split(key, 3)
        q = rand(ks[0], (2, 64, 4, 16), jnp.float32)
        k = rand(ks[1], (2, 64, 2, 16), jnp.float32)
        v = rand(ks[2], (2, 64, 2, 16), jnp.float32)
        got = ops.flash_attention(q, k, v, impl="pallas")
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-3)


class TestRMSNorm:
    @settings(max_examples=20, deadline=None)
    @given(rows=st.integers(1, 300), d=st.sampled_from([8, 64, 128, 512]),
           seed=st.integers(0, 99))
    def test_matches_ref_hypothesis(self, rows, d, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (rows, d))
        s = jax.random.normal(k2, (d,)) * 0.1 + 1.0
        got = rmsnorm_pallas(x, s, row_tile=64)
        want = ref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)

    @pytest.mark.parametrize("shape", [(5, 7, 64), (2, 3, 4, 32), (128,)])
    def test_nd_shapes(self, shape):
        key = jax.random.PRNGKey(1)
        x = jax.random.normal(key, shape)
        s = jnp.ones((shape[-1],))
        got = rmsnorm_pallas(x, s)
        want = ref.rmsnorm_ref(x, s)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


class TestOpsSelection:
    def test_default_on_cpu_is_ref(self):
        assert ops.default_impl() in ("ref", "pallas")

    def test_conv_grad_via_ref(self):
        """The ref conv path is differentiable (used by CNN training)."""
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (1, 8, 8, 2))
        w = jax.random.normal(k2, (3, 3, 2, 4))
        g = jax.grad(lambda w_: ops.conv2d(x, w_, impl="ref").sum())(w)
        assert g.shape == w.shape and float(jnp.abs(g).sum()) > 0

    def test_conv_grad_pallas_matches_ref_dispatch(self):
        """Both dispatch impls agree on value AND gradient (fused epilogue)."""
        key = jax.random.PRNGKey(9)
        k1, k2, k3 = jax.random.split(key, 3)
        x = jax.random.normal(k1, (2, 8, 8, 2))
        w = jax.random.normal(k2, (3, 3, 2, 4))
        b = jax.random.normal(k3, (4,))

        def loss(impl):
            def f(w_, b_):
                out = ops.conv2d(x, w_, b_, activation="relu", impl=impl)
                return jnp.sum(out ** 2)
            return f

        vp, (gwp, gbp) = jax.value_and_grad(loss("pallas"), (0, 1))(w, b)
        vr, (gwr, gbr) = jax.value_and_grad(loss("ref"), (0, 1))(w, b)
        np.testing.assert_allclose(float(vp), float(vr), rtol=1e-5)
        np.testing.assert_allclose(gwp, gwr, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(gbp, gbr, atol=1e-4, rtol=1e-4)

    def test_mixed_precision_output_dtype_agrees(self):
        """bf16 x/w with an f32 master bias: both impls emit bf16."""
        key = jax.random.PRNGKey(21)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (1, 8, 8, 2), jnp.bfloat16)
        w = rand(k2, (3, 3, 2, 4), jnp.bfloat16)
        b = jnp.zeros((4,), jnp.float32)
        out_p = ops.conv2d(x, w, b, activation="relu", impl="pallas")
        out_r = ops.conv2d(x, w, b, activation="relu", impl="ref")
        assert out_p.dtype == out_r.dtype == jnp.bfloat16

    def test_conv_oc_tile_auto_uses_dag_cost_model(self):
        """oc_tile=None resolves through core.dag.choose_oc_tile."""
        from repro.core.dag import choose_oc_tile
        key = jax.random.PRNGKey(10)
        k1, k2 = jax.random.split(key)
        x = jax.random.normal(k1, (2, 8, 8, 3))
        w = jax.random.normal(k2, (3, 3, 3, 16))
        tile = choose_oc_tile(2, 16)
        assert 16 % tile == 0
        auto = ops.conv2d(x, w, impl="pallas")
        explicit = ops.conv2d(x, w, impl="pallas", oc_tile=tile)
        np.testing.assert_allclose(auto, explicit, atol=1e-6)
