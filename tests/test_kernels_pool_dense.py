"""Pool + dense Pallas kernels: allclose sweeps vs the ref.py oracles,
the grad-check matrix (window/block x activation x jit+vmap+grad), the
Alg. 4.2 block auto-selection, and the explicit-fallback contract."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dag import choose_fc_block
from repro.kernels import ops, ref
from repro.kernels.dense import dense_pallas
from repro.kernels.pool2d import max_pool2d_pallas


def rand(key, shape, dtype=jnp.float32):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.fixture(autouse=True)
def _clean_fallback_log():
    ops.clear_fallback_log()
    yield
    ops.clear_fallback_log()


# ----------------------------------------------------------------------
# max_pool2d
# ----------------------------------------------------------------------
class TestMaxPool2d:
    SHAPES = [
        (1, 8, 8, 1, 2),
        (2, 16, 16, 3, 2),
        (2, 12, 12, 4, 4),
        (1, 9, 7, 2, 2),           # odd spatial: remainder dropped
        (2, 8, 8, 3, 8),           # window == whole map
    ]

    @pytest.mark.parametrize("B,H,W,C,window", SHAPES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_forward_matches_ref(self, B, H, W, C, window, dtype):
        key = jax.random.PRNGKey(hash((B, H, W, C, window)) % 2**31)
        x = rand(key, (B, H, W, C), dtype)
        got = max_pool2d_pallas(x, window=window, stride=window)
        want = ref.max_pool2d_ref(x, window=window, stride=window)
        assert got.dtype == x.dtype
        np.testing.assert_allclose(got.astype(jnp.float32),
                                   want.astype(jnp.float32), atol=0)

    @pytest.mark.parametrize("B,H,W,C,window", SHAPES)
    def test_grads_match_ref(self, B, H, W, C, window):
        key = jax.random.PRNGKey(hash(("g", B, H, W, C, window)) % 2**31)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (B, H, W, C))
        cot = rand(k2, (B, H // window, W // window, C))
        got = jax.grad(lambda x_: jnp.sum(
            max_pool2d_pallas(x_, window=window, stride=window) * cot))(x)
        want = jax.grad(lambda x_: jnp.sum(
            ref.max_pool2d_ref(x_, window=window, stride=window) * cot))(x)
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_tie_routing_splits_evenly(self):
        """Relu feature maps tie constantly (exact zeros); the Eq. 18
        routing must split tied maxima evenly like jax.grad of the ref,
        or the pallas ≡ ref trajectory equivalence breaks."""
        key = jax.random.PRNGKey(3)
        k1, k2 = jax.random.split(key)
        # quantize hard so nearly every window has tied maxima
        x = jnp.round(jax.nn.relu(rand(k1, (2, 8, 8, 3))) * 2) / 2
        cot = rand(k2, (2, 4, 4, 3))
        got = jax.grad(lambda x_: jnp.sum(max_pool2d_pallas(x_) * cot))(x)
        want = jax.grad(lambda x_: jnp.sum(ref.max_pool2d_ref(x_) * cot))(x)
        assert float(jnp.abs(want).max()) > 0
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_grad_under_jit_and_vmap(self):
        """The fused trainer wraps pooling in jit(vmap(grad(...)))."""
        key = jax.random.PRNGKey(5)
        x = rand(key, (3, 2, 8, 8, 2))                   # (m, B, H, W, C)
        got = jax.jit(jax.vmap(jax.grad(
            lambda x_: jnp.sum(max_pool2d_pallas(x_) ** 2))))(x)
        want = jax.vmap(jax.grad(
            lambda x_: jnp.sum(ref.max_pool2d_ref(x_) ** 2)))(x)
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_overlapping_window_raises(self):
        x = rand(jax.random.PRNGKey(0), (1, 8, 8, 1))
        with pytest.raises(ValueError, match="non-overlapping"):
            max_pool2d_pallas(x, window=3, stride=2)

    def test_window_larger_than_input_raises(self):
        x = rand(jax.random.PRNGKey(0), (1, 4, 4, 1))
        with pytest.raises(ValueError, match="smaller than"):
            max_pool2d_pallas(x, window=8, stride=8)

    def test_dispatch_impls_agree(self):
        x = rand(jax.random.PRNGKey(7), (2, 10, 10, 3))
        got = ops.max_pool2d(x, impl="pallas")
        want = ops.max_pool2d(x, impl="ref")
        np.testing.assert_allclose(got, want, atol=0)
        assert ops.fallback_events() == {}


# ----------------------------------------------------------------------
# dense
# ----------------------------------------------------------------------
def _dense_grid():
    # seed, block, activation, bias, shape (B, Din, Dout)
    return [
        (0, 0, "none", True, (4, 12, 8)),
        (1, 0, "relu", True, (4, 12, 8)),
        (2, 4, "none", True, (4, 12, 8)),
        (3, 4, "relu", True, (4, 12, 8)),
        (4, 8, "relu", True, (2, 16, 8)),    # block == Dout
        (5, 2, "none", False, (1, 6, 10)),   # no bias, odd dims
        (6, 5, "relu", False, (3, 7, 10)),   # Din not divisible by block
    ]


class TestDensePallas:
    @pytest.mark.parametrize("seed,block,activation,bias,shape",
                             _dense_grid())
    def test_forward_matches_ref(self, seed, block, activation, bias, shape):
        B, Din, Dout = shape
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        x = rand(k1, (B, Din))
        w = rand(k2, (Din, Dout))
        b = rand(k3, (Dout,)) if bias else None
        got = dense_pallas(x, w, b, activation=activation, block=block)
        want = ref.dense_ref(x, w, b, activation=activation)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("seed,block,activation,bias,shape",
                             _dense_grid())
    def test_grads_match_ref(self, seed, block, activation, bias, shape):
        """The §4.1.2 G_FC gradient tasks: dx/dw/db vs the jnp oracle."""
        B, Din, Dout = shape
        key = jax.random.PRNGKey(100 + seed)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        x = rand(k1, (B, Din))
        w = rand(k2, (Din, Dout))
        b = rand(k3, (Dout,)) if bias else jnp.zeros((Dout,))
        cot = rand(k4, (B, Dout))              # non-uniform cotangent

        def loss_pallas(x_, w_, b_):
            return jnp.sum(dense_pallas(x_, w_, b_, activation=activation,
                                        block=block) * cot)

        def loss_ref(x_, w_, b_):
            return jnp.sum(ref.dense_ref(x_, w_, b_,
                                         activation=activation) * cot)

        got = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
        want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
        for g, r, name in zip(got, want, ("dx", "dw", "db"), strict=True):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                       atol=1e-4, rtol=1e-4,
                                       err_msg=f"{name} mismatch")

    def test_grad_under_jit_and_vmap(self):
        """The fused trainer wraps the FC stack in jit(vmap(grad(...)))."""
        key = jax.random.PRNGKey(9)
        k1, k2, k3 = jax.random.split(key, 3)
        x = rand(k1, (3, 4, 12))                          # (m, B, Din)
        w = rand(k2, (12, 8))
        b = rand(k3, (8,))

        def loss(x_):
            return jnp.sum(dense_pallas(x_, w, b, activation="relu",
                                        block=4))

        got = jax.jit(jax.vmap(jax.grad(loss)))(x)
        want = jax.vmap(jax.grad(lambda x_: jnp.sum(
            ref.dense_ref(x_, w, b, activation="relu"))))(x)
        np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)

    def test_mixed_precision_dtypes(self):
        """bf16 x/w with an f32 master bias: bf16 out, f32 db."""
        key = jax.random.PRNGKey(11)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (2, 8), jnp.bfloat16)
        w = rand(k2, (8, 4), jnp.bfloat16)
        b = jnp.zeros((4,), jnp.float32)
        out = dense_pallas(x, w, b)
        assert out.dtype == jnp.bfloat16
        db = jax.grad(lambda b_: jnp.sum(
            dense_pallas(x, w, b_).astype(jnp.float32)))(b)
        assert db.dtype == jnp.float32

    def test_non_divisor_block_raises(self):
        x = rand(jax.random.PRNGKey(0), (1, 8))
        w = rand(jax.random.PRNGKey(1), (8, 8))
        with pytest.raises(ValueError, match="block"):
            dense_pallas(x, w, block=3)

    def test_nd_input_rejected_at_kernel_level(self):
        x = rand(jax.random.PRNGKey(0), (2, 3, 8))
        w = rand(jax.random.PRNGKey(1), (8, 4))
        with pytest.raises(ValueError, match="2-D"):
            dense_pallas(x, w)

    def test_ops_dense_flattens_leading_dims(self):
        """ops.dense takes (B, S, D) like the LM matmul sites."""
        key = jax.random.PRNGKey(13)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (2, 5, 12))
        w = rand(k2, (12, 8))
        got = ops.dense(x, w, impl="pallas")
        want = ops.dense(x, w, impl="ref")
        assert got.shape == (2, 5, 8)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)

    def test_block_auto_uses_dag_cost_model(self):
        """block=None resolves through core.dag.choose_fc_block."""
        key = jax.random.PRNGKey(15)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (2, 8))
        w = rand(k2, (8, 32))
        block = choose_fc_block(32)
        assert 32 % block == 0
        auto = ops.dense(x, w, impl="pallas")
        explicit = ops.dense(x, w, impl="pallas", block=block)
        np.testing.assert_allclose(auto, explicit, atol=1e-6)

    def test_dispatch_grads_agree(self):
        """Both dispatch impls agree on value AND gradient (fused epilogue)."""
        key = jax.random.PRNGKey(17)
        k1, k2, k3 = jax.random.split(key, 3)
        x = rand(k1, (4, 12))
        w = rand(k2, (12, 8))
        b = rand(k3, (8,))

        def loss(impl):
            def f(w_, b_):
                return jnp.sum(
                    ops.dense(x, w_, b_, activation="relu", impl=impl) ** 2)
            return f

        vp, (gwp, gbp) = jax.value_and_grad(loss("pallas"), (0, 1))(w, b)
        vr, (gwr, gbr) = jax.value_and_grad(loss("ref"), (0, 1))(w, b)
        np.testing.assert_allclose(float(vp), float(vr), rtol=1e-5)
        np.testing.assert_allclose(gwp, gwr, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(gbp, gbr, atol=1e-4, rtol=1e-4)


# ----------------------------------------------------------------------
# the explicit-fallback contract
# ----------------------------------------------------------------------
class TestFallbackContract:
    def _conv_args(self):
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        return rand(k1, (1, 8, 8, 2)), rand(k2, (3, 3, 2, 4))

    def test_explicit_pallas_strided_conv_raises(self):
        x, w = self._conv_args()
        with pytest.raises(NotImplementedError, match="stride"):
            ops.conv2d(x, w, stride=2, impl="pallas")

    def test_env_pallas_strided_conv_warns_once_and_records(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        x, w = self._conv_args()
        with pytest.warns(ops.KernelFallbackWarning, match="stride"):
            got = ops.conv2d(x, w, stride=2)
        np.testing.assert_allclose(
            got, ops.conv2d(x, w, stride=2, impl="ref"), atol=1e-6)
        events = ops.fallback_events()
        assert len(events) == 1 and next(iter(events))[0] == "conv2d"
        # second identical call: recorded, but NOT warned again
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            ops.conv2d(x, w, stride=2)
        assert next(iter(ops.fallback_events().values())) == 2

    def test_explicit_pallas_overlapping_pool_raises(self):
        x = rand(jax.random.PRNGKey(1), (1, 8, 8, 2))
        with pytest.raises(NotImplementedError, match="window"):
            ops.max_pool2d(x, window=3, stride=1, impl="pallas")

    def test_env_pallas_overlapping_pool_warns_and_records(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        x = rand(jax.random.PRNGKey(1), (1, 8, 8, 2))
        # the jnp ref is non-overlapping-only too: the fallback is
        # recorded+warned first, then the ref raises loudly — never a
        # silently wrong pooling result
        with pytest.warns(ops.KernelFallbackWarning, match="window"):
            with pytest.raises(ValueError):
                ops.max_pool2d(x, window=3, stride=1)
        assert any(op == "max_pool2d" for op, _ in ops.fallback_events())

    def test_explicit_pallas_oversized_dense_raises(self):
        """A grid cell past the VMEM budget cannot be served: the kernel
        has no row/K tiling, so a transformer-scale matmul must not be
        silently attempted (or silently ref'd)."""
        x = jnp.ones((9000, 256), jnp.float32)       # ~9.4 MiB cell
        w = jnp.ones((256, 8), jnp.float32)
        with pytest.raises(NotImplementedError, match="VMEM budget"):
            ops.dense(x, w, impl="pallas")

    def test_env_pallas_oversized_dense_warns_and_uses_ref(self,
                                                          monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        x = jnp.ones((9000, 256), jnp.float32)
        w = jnp.ones((256, 8), jnp.float32)
        with pytest.warns(ops.KernelFallbackWarning, match="VMEM budget"):
            got = ops.dense(x, w)
        np.testing.assert_allclose(got, ops.dense(x, w, impl="ref"),
                                   atol=1e-5)
        assert any(op == "dense" for op, _ in ops.fallback_events())

    def test_dense_mixed_precision_matches_ref_dtype_path(self):
        """bf16 activations with f32 master weights: the pallas dispatch
        casts w to x.dtype like the ref, keeping parity (and halving the
        weight-panel traffic on real hardware)."""
        key = jax.random.PRNGKey(23)
        k1, k2 = jax.random.split(key)
        x = rand(k1, (4, 16), jnp.bfloat16)
        w = rand(k2, (16, 8), jnp.float32)
        got = ops.dense(x, w, impl="pallas")
        want = ops.dense(x, w, impl="ref")
        assert got.dtype == want.dtype == jnp.bfloat16
        np.testing.assert_allclose(got.astype(jnp.float32),
                                   want.astype(jnp.float32),
                                   atol=0.1, rtol=0.05)

    def test_pallas_paths_log_nothing(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas")
        x, w = self._conv_args()
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            ops.conv2d(x, w)                       # stride-1: pallas serves
            ops.max_pool2d(x)                      # non-overlapping: serves
            ops.dense(x.reshape(1, -1), rand(jax.random.PRNGKey(3),
                                             (128, 8)))
        assert ops.fallback_events() == {}
