"""Per-assigned-architecture smoke tests (reduced configs).

For each of the 10 architectures: instantiate the REDUCED variant of the
same family, run one forward/train step on CPU, assert output shapes and
no NaNs.  Decode smoke for every arch with a decode path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import encdec, lm
from repro.models.frontends import random_frontend_embeds
from repro.optim.optimizers import adamw, apply_updates

ARCHS = list(configs.ARCH_NAMES)


def make_batch(cfg, B=2, S=16, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.arch_type == "encdec":
        batch["frontend_embeds"] = jax.random.normal(
            k1, (B, 8, cfg.d_model), jnp.bfloat16)
    elif cfg.frontend:
        batch["frontend_embeds"] = random_frontend_embeds(k1, cfg, B)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_full_config_exact(self, arch):
        """The full config matches the assignment numbers exactly."""
        cfg = configs.get_config(arch)
        expected = {
            "yi-6b": (32, 4096, 32, 4, 11008, 64000),
            "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
            "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
            "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
            "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
            "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
            "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
            "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 0, 151936),
            "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
            "granite-moe-3b-a800m": (32, 1536, 24, 8, 0, 49155),
        }[arch]
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == expected

    def test_reduced_constraints(self, arch):
        r = configs.get_reduced(arch)
        assert r.num_layers == 2
        assert r.d_model <= 512
        assert r.num_experts <= 4

    def test_train_step(self, arch):
        cfg = configs.get_reduced(arch)
        key = jax.random.PRNGKey(0)
        batch = make_batch(cfg)
        if cfg.arch_type == "encdec":
            params = encdec.init_encdec_params(key, cfg)

            def loss_fn(p, b):
                return encdec.encdec_loss_fn(p, b, cfg)[0]
        else:
            params = lm.init_params(key, cfg)

            def loss_fn(p, b):
                return lm.loss_fn(p, b, cfg)[0]
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        assert np.isfinite(float(loss))
        opt = adamw()
        st = opt.init(params)
        upd, st = opt.update(grads, st, params, 1e-3)
        new_params = apply_updates(params, upd)
        loss2 = loss_fn(new_params, batch)
        assert np.isfinite(float(loss2))
        for leaf in jax.tree_util.tree_leaves(new_params):
            assert not bool(jnp.isnan(leaf).any())

    def test_forward_shapes(self, arch):
        cfg = configs.get_reduced(arch)
        key = jax.random.PRNGKey(0)
        B, S = 2, 16
        batch = make_batch(cfg, B, S)
        if cfg.arch_type == "encdec":
            params = encdec.init_encdec_params(key, cfg)
            hidden = encdec.encdec_forward(
                params, batch["frontend_embeds"], batch["tokens"], cfg)
            assert hidden.shape == (B, S, cfg.d_model)
        else:
            params = lm.init_params(key, cfg)
            hidden, _, aux = lm.forward(
                params, batch["tokens"], cfg,
                frontend_embeds=batch.get("frontend_embeds"))
            extra = cfg.num_frontend_tokens if cfg.frontend else 0
            assert hidden.shape == (B, S + extra, cfg.d_model)
        assert not bool(jnp.isnan(hidden.astype(jnp.float32)).any())

    def test_decode_step(self, arch):
        cfg = configs.get_reduced(arch)
        key = jax.random.PRNGKey(0)
        B, S = 2, 8
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
        if cfg.arch_type == "encdec":
            params = encdec.init_encdec_params(key, cfg)
            cache = encdec.init_encdec_cache(cfg, B, S, enc_len=8)
            logits, cache2 = encdec.encdec_decode_step(
                params, cache, jnp.int32(0), tok, cfg)
        else:
            params = lm.init_params(key, cfg)
            cache = lm.init_cache(B, S, cfg)
            logits, cache2 = lm.decode_step(params, cache, jnp.int32(0),
                                            tok, cfg)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        assert jax.tree_util.tree_structure(cache) == \
            jax.tree_util.tree_structure(cache2)
