"""reprolint fixture suite: every rule exercised on good and bad
in-memory snippets, suppression-comment semantics, the trace-scope
closure (nested jit scopes, aliases, the timer allowlist), the CLI
surface, and the self-check that the repo itself lints clean.

These tests are pure stdlib + the in-tree linter — no JAX import — so
they run first and fast.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

from tools.reprolint import (ALL_RULES, lint_paths, lint_source,
                             lint_sources)

REPO = Path(__file__).resolve().parents[1]


def rules_of(findings):
    return [f.rule for f in findings]


def lint(src, path="snippet.py", only=None):
    return lint_source(textwrap.dedent(src), path=path, only=only)


# ----------------------------------------------------------------------
# RPL101/RPL102 — single decision point
# ----------------------------------------------------------------------
class TestDispatchRules:
    def test_flags_config_attribute_read(self):
        out = lint("""
            def pick(tc):
                if tc.fused_outer:
                    return "fused"
            """, path="src/repro/core/bpt_trainer.py", only=["RPL101"])
        assert rules_of(out) == ["RPL101"]
        assert "fused_outer" in out[0].message

    def test_flags_getattr_spelling(self):
        out = lint("""
            def pick(cfg):
                return getattr(cfg, "mesh_name")
            """, path="src/repro/core/x.py", only=["RPL101"])
        assert rules_of(out) == ["RPL101"]

    def test_dotted_receiver_terminal_matches(self):
        out = lint("""
            class T:
                def go(self):
                    return self.tc.device_outer
            """, path="src/repro/core/x.py", only=["RPL101"])
        assert rules_of(out) == ["RPL101"]

    def test_engine_module_is_allowed(self):
        out = lint("""
            def resolve_engine(tc):
                return tc.fused_outer, tc.mesh_name
            """, path="src/repro/core/engine.py", only=["RPL101"])
        assert out == []

    def test_non_config_receiver_is_clean(self):
        out = lint("""
            def run(args, plan):
                return args.batching, plan.batching
            """, path="src/repro/launch/serve.py", only=["RPL102"])
        assert out == []

    def test_constructor_keyword_is_clean(self):
        out = lint("""
            def mk():
                return TrainConfig(fused_outer=True, mesh_name="pod")
            """, path="src/repro/core/x.py", only=["RPL101"])
        assert out == []

    def test_serve_fields_flag_outside_serving_engine(self):
        out = lint("""
            def pick(sc):
                return sc.batching == "continuous" and sc.timing
            """, path="src/repro/serving/cache.py", only=["RPL102"])
        assert sorted(rules_of(out)) == ["RPL102", "RPL102"]


# ----------------------------------------------------------------------
# RPL201/RPL202 — trace hygiene
# ----------------------------------------------------------------------
class TestTraceRules:
    def test_host_sync_in_jitted_function(self):
        out = lint("""
            import jax

            @jax.jit
            def step(x):
                jax.block_until_ready(x)
                return x
            """, only=["RPL201"])
        assert rules_of(out) == ["RPL201"]
        assert "block_until_ready" in out[0].message

    def test_callsite_wrapping_and_transitive_reach(self):
        # helper() is only reachable through step(), which is jitted at
        # a call site — the closure must follow both hops
        out = lint("""
            import jax, numpy as np

            def helper(x):
                return np.asarray(x)

            def step(x):
                return helper(x)

            run = jax.jit(step)
            """, only=["RPL201"])
        assert rules_of(out) == ["RPL201"]
        assert "np.asarray" in out[0].message

    def test_nested_jit_scope_inner_def(self):
        # a def nested inside a traced def runs at trace time too
        out = lint("""
            import jax, time

            @jax.jit
            def outer(x):
                def inner(y):
                    return y * time.perf_counter()
                return inner(x)
            """, only=["RPL202"])
        assert rules_of(out) == ["RPL202"]
        assert "inner" in out[0].message or "outer" in out[0].message

    def test_partial_decorator_and_scan_body(self):
        out = lint("""
            import jax, random
            from functools import partial

            def body(carry, x):
                return carry + random.random(), x

            def roll(xs):
                return jax.lax.scan(body, 0.0, xs)
            """, only=["RPL202"])
        assert rules_of(out) == ["RPL202"]

    def test_untraced_function_is_clean(self):
        out = lint("""
            import time

            def bench(f):
                t0 = time.perf_counter()
                f()
                return time.perf_counter() - t0
            """, only=["RPL201", "RPL202"])
        assert out == []

    def test_jax_random_is_not_nondet(self):
        out = lint("""
            import jax

            @jax.jit
            def draw(key):
                return jax.random.normal(key, (4,))
            """, only=["RPL202"])
        assert out == []

    def test_timer_allowlist_exempts_measured_timer(self):
        out = lint("""
            import time, jax

            class MeasuredTimer:
                def call(self, f, x):
                    t0 = time.perf_counter()
                    y = jax.block_until_ready(f(x))
                    return y, time.perf_counter() - t0

            probe = jax.jit(MeasuredTimer.call)
            """, only=["RPL201", "RPL202"])
        assert out == []

    def test_item_pull_flags_but_methodful_item_does_not(self):
        out = lint("""
            import jax

            @jax.jit
            def bad(x):
                return float(x.item())

            @jax.jit
            def fine(d):
                return d.item(0)
            """, only=["RPL201"])
        assert rules_of(out) == ["RPL201"]
        assert out[0].line == 6


# ----------------------------------------------------------------------
# RPL301/RPL302/RPL303 — kernel contracts
# ----------------------------------------------------------------------
KERNEL_OK = """
import jax
from jax.experimental import pallas as pl

@jax.custom_vjp
def dense_pallas(x, w):
    return pl.pallas_call(lambda r: r)(x, w)

def _fwd(x, w):
    return dense_pallas(x, w), (x, w)

def _bwd(res, g):
    return g, g

dense_pallas.defvjp(_fwd, _bwd)
"""

KERNEL_NO_VJP = """
from jax.experimental import pallas as pl

def dense_pallas(x, w):
    return pl.pallas_call(lambda r: r)(x, w)
"""

OPS_ROUTING = """
def dense(x, w, impl="auto"):
    if impl == "pallas":
        try:
            from . import dense_kernel
            return dense_kernel.dense_pallas(x, w)
        except Exception as e:
            _fallback("dense", str(e), explicit=(impl == "pallas"))
    return _dense_ref(x, w)
"""


class TestKernelRules:
    def test_missing_vjp_flags(self):
        out = lint_sources(
            {"src/repro/kernels/dense_kernel.py": KERNEL_NO_VJP},
            only=["RPL301"])
        assert rules_of(out) == ["RPL301"]
        assert "dense_pallas" in out[0].message

    def test_paired_vjp_is_clean(self):
        out = lint_sources(
            {"src/repro/kernels/dense_kernel.py": KERNEL_OK},
            only=["RPL301"])
        assert out == []

    def test_rule_skips_non_kernel_modules(self):
        out = lint_sources({"src/repro/models/cnn.py": KERNEL_NO_VJP},
                           only=["RPL301"])
        assert out == []

    def test_unrouted_kernel_flags(self):
        out = lint_sources({
            "src/repro/kernels/dense_kernel.py": KERNEL_OK,
            "src/repro/kernels/ops.py": "def dense(x, w):\n    return x\n",
        }, only=["RPL303"])
        assert rules_of(out) == ["RPL303"]

    def test_routed_kernel_is_clean(self):
        out = lint_sources({
            "src/repro/kernels/dense_kernel.py": KERNEL_OK,
            "src/repro/kernels/ops.py": OPS_ROUTING,
        }, only=["RPL303"])
        assert out == []

    def test_silent_fallback_flags(self):
        out = lint("""
            def dense(x, w, impl="auto"):
                if impl == "pallas":
                    y = _try_kernel(x, w)
                return _dense_ref(x, w)
            """, path="src/repro/kernels/ops.py", only=["RPL302"])
        assert rules_of(out) == ["RPL302"]

    def test_fallback_contract_is_clean(self):
        out = lint_sources({"src/repro/kernels/ops.py": OPS_ROUTING},
                           only=["RPL302"])
        assert out == []

    def test_suite_ending_in_return_is_clean(self):
        out = lint("""
            def rmsnorm(x, s, impl="auto"):
                if impl == "pallas":
                    return _rmsnorm_pallas(x, s)
                return _rmsnorm_ref(x, s)
            """, path="src/repro/kernels/ops.py", only=["RPL302"])
        assert out == []


# ----------------------------------------------------------------------
# RPL401/RPL402/RPL403 — deprecation bans
# ----------------------------------------------------------------------
class TestDeprecationRules:
    def test_greedy_generate_import_and_call_flag(self):
        out = lint("""
            from repro.launch.serve import greedy_generate

            def go(params, cfg, prompts):
                return greedy_generate(params, cfg, prompts, 16, 4)
            """, path="examples/demo.py", only=["RPL401"])
        assert rules_of(out) == ["RPL401", "RPL401"]

    def test_shim_module_is_allowed(self):
        out = lint("def greedy_generate(*a):\n    return None\n",
                   path="src/repro/launch/serve.py", only=["RPL401"])
        assert out == []

    def test_legacy_init_cache_order_flags(self):
        out = lint("""
            def warm(cfg):
                return init_cache(cfg, 2, 16)
            """, only=["RPL402"])
        assert rules_of(out) == ["RPL402"]

    def test_legacy_getattr_spelling_flags(self):
        out = lint("""
            def warm(lm, cfg):
                return getattr(lm, "init_cache")(cfg, 2, 16)
            """, only=["RPL402"])
        assert rules_of(out) == ["RPL402"]

    def test_new_order_is_clean(self):
        out = lint("""
            def warm(cfg):
                return init_cache(2, 16, cfg=cfg)
            """, only=["RPL402"])
        assert out == []

    def test_pythonpath_runline_flags_with_line_number(self):
        out = lint('''
            """Driver.

                PYTHONPATH=src python -m repro.launch.x --go
            """
            X = 1
            ''', only=["RPL403"])
        assert rules_of(out) == ["RPL403"]
        assert out[0].line == 4

    def test_prose_mention_is_clean(self):
        out = lint('''
            """Driver.

                python -m repro.launch.x --go

            (bare checkouts can prefix ``PYTHONPATH=src``.)
            """
            ''', only=["RPL403"])
        assert out == []


# ----------------------------------------------------------------------
# RPL501 — donation safety
# ----------------------------------------------------------------------
class TestDonationRule:
    def test_reuse_after_donation_flags(self):
        out = lint("""
            import jax

            def train(step, params, opt, batches):
                run = jax.jit(step, donate_argnums=(0,))
                out = run(params, opt)
                return params["w"]
            """, only=["RPL501"])
        assert rules_of(out) == ["RPL501"]
        assert "`params`" in out[0].message

    def test_rebind_from_result_is_clean(self):
        out = lint("""
            import jax

            def train(step, params, opt, batches):
                run = jax.jit(step, donate_argnums=(0, 1))
                for b in batches:
                    params, opt = run(params, opt)
                return params
            """, only=["RPL501"])
        assert out == []

    def test_non_donated_arg_is_clean(self):
        out = lint("""
            import jax

            def train(step, params, opt):
                run = jax.jit(step, donate_argnums=(0,))
                new_params = run(params, opt)
                return opt
            """, only=["RPL501"])
        assert out == []

    def test_donation_does_not_leak_across_functions(self):
        out = lint("""
            import jax

            def a(step, params):
                run = jax.jit(step, donate_argnums=(0,))
                return run(params)

            def b(params):
                return params
            """, only=["RPL501"])
        assert out == []


# ----------------------------------------------------------------------
# suppressions, parse errors, engine surface
# ----------------------------------------------------------------------
class TestEngineBehaviour:
    def test_inline_suppression_by_id_and_name(self):
        src = """
            def pick(tc):
                a = tc.fused_outer  # reprolint: disable=RPL101
                b = tc.device_outer  # reprolint: disable=dispatch-train
                c = tc.mesh_name
            """
        out = lint(src, path="src/repro/core/x.py", only=["RPL101"])
        assert len(out) == 1 and out[0].line == 5

    def test_suppress_all_token(self):
        out = lint(
            "def f(tc):\n"
            "    return tc.fused_outer  # reprolint: disable=all\n",
            path="src/repro/core/x.py", only=["RPL101"])
        assert out == []

    def test_suppression_is_line_scoped(self):
        out = lint(
            "# reprolint: disable=RPL101\n"
            "def f(tc):\n"
            "    return tc.fused_outer\n",
            path="src/repro/core/x.py", only=["RPL101"])
        assert len(out) == 1

    def test_parse_error_reports_rpl000_unsuppressable(self):
        out = lint_source(
            "def broken(:  # reprolint: disable=all\n")
        assert rules_of(out) == ["RPL000"]

    def test_unknown_rule_selection_raises(self):
        try:
            lint_source("x = 1\n", only=["RPL999"])
        except ValueError as e:
            assert "RPL999" in str(e)
        else:
            raise AssertionError("expected ValueError")

    def test_rule_ids_unique_and_named(self):
        ids = [r.id for r in ALL_RULES]
        names = [r.name for r in ALL_RULES]
        assert len(set(ids)) == len(ids)
        assert len(set(names)) == len(names)
        assert all(r.description for r in ALL_RULES)

    def test_findings_sorted_and_formatted(self):
        out = lint("""
            def pick(tc):
                b = tc.device_outer
                a = tc.fused_outer
            """, path="src/repro/core/x.py", only=["RPL101"])
        assert [f.line for f in out] == sorted(f.line for f in out)
        assert out[0].format().startswith("src/repro/core/x.py:3:")


# ----------------------------------------------------------------------
# RPL601–RPL605 — shardcheck: mesh/collective static analysis
# ----------------------------------------------------------------------
class TestShardcheckRules:
    def test_axis_unbound_by_enclosing_mesh_flags(self):
        """psum("model") inside a shard_map over a 1-D `nodes` mesh: the
        axis exists in the repo vocabulary but is NOT bound here."""
        out = lint("""
            import jax
            from jax.experimental.shard_map import shard_map
            from repro.launch.mesh import make_nodes_mesh
            mesh = make_nodes_mesh(4)
            def body(x):
                return jax.lax.psum(x, "model")
            sm = shard_map(body, mesh=mesh, in_specs=None, out_specs=None)
            """, path="src/repro/core/x.py", only=["RPL601"])
        assert rules_of(out) == ["RPL601"]
        assert "'model'" in out[0].message and "nodes" in out[0].message

    def test_axis_bound_by_hybrid_mesh_is_clean(self):
        out = lint("""
            import jax
            from jax.experimental.shard_map import shard_map
            from repro.launch.mesh import make_hybrid_mesh
            mesh = make_hybrid_mesh(4, 2)
            def body(x):
                i = jax.lax.axis_index("nodes")
                return jax.lax.psum(x, "model") + i
            sm = shard_map(body, mesh=mesh, in_specs=None, out_specs=None)
            """, path="src/repro/core/x.py", only=["RPL601"])
        assert out == []

    def test_axis_outside_vocabulary_flags_anywhere(self):
        out = lint("""
            import jax
            def f(x):
                return jax.lax.all_gather(x, "banana")
            """, path="src/repro/models/y.py", only=["RPL601"])
        assert rules_of(out) == ["RPL601"]
        assert "banana" in out[0].message

    def test_named_mesh_resolves_through_registry(self):
        """make_mesh("hyb") binds (nodes, model) cross-FILE through the
        MESHES dict in the project's launch/mesh.py."""
        out = lint_sources({
            "launch/mesh.py": textwrap.dedent("""
                MESHES = {
                    "hyb": ((4, 2), ("nodes", "model")),
                    "flat": ((8,), ("data",)),
                }
                """),
            "core/x.py": textwrap.dedent("""
                import jax
                from jax.experimental.shard_map import shard_map
                from repro.launch.mesh import make_mesh
                mesh = make_mesh("hyb")
                def body(x):
                    return jax.lax.psum(x, "data")
                sm = shard_map(body, mesh=mesh, in_specs=None,
                               out_specs=None)
                """),
        }, only=["RPL601"])
        assert rules_of(out) == ["RPL601"]
        assert "'data'" in out[0].message and "nodes" in out[0].message

    def test_unresolvable_axis_name_is_skipped(self):
        """Axis names flowing through parameters (planner idiom
        ``axis = plan.axis``) are skipped, not guessed."""
        out = lint("""
            import jax
            def combine(loss, axis):
                return jax.lax.psum(loss, axis)
            """, path="src/repro/core/x.py", only=["RPL601"])
        assert out == []

    def test_axis_default_parameter_resolves(self):
        out = lint("""
            import jax
            def f(x, axis_name="bogus"):
                return jax.lax.psum(x, axis_name)
            """, path="src/repro/core/x.py", only=["RPL601"])
        assert rules_of(out) == ["RPL601"]

    def test_eq7_merge_over_model_flags(self):
        """THE fixture of the PR: a mis-axed Eq. 7 merge — psum over
        `model` inside the GWU scope merges the wrong groups."""
        out = lint("""
            import jax
            def _sharded_merge_fn(mesh):
                def body(stack, w):
                    return jax.lax.psum(stack * w, "model")
                return body
            """, path="src/repro/core/gwu.py", only=["RPL602"])
        assert rules_of(out) == ["RPL602"]
        assert "'model'" in out[0].message and "nodes" in out[0].message

    def test_eq7_merge_over_nodes_is_clean(self):
        out = lint("""
            import jax
            def sgwu_merge(stack, w):
                i = jax.lax.axis_index("model")   # index read: not a merge
                return jax.lax.psum(stack * w, "nodes")
            """, path="src/repro/core/x.py", only=["RPL602"])
        assert out == []

    def test_planner_model_psum_is_out_of_eq7_scope(self):
        out = lint("""
            import jax
            def grad_combine_over_model(loss):
                return jax.lax.psum(loss, "model")
            """, path="src/repro/core/planner.py", only=["RPL602"])
        assert out == []

    def test_orphan_spec_outside_owner_flags(self):
        out = lint("""
            from jax.sharding import PartitionSpec as P
            SPEC = P("nodes")
            """, path="src/repro/core/x.py", only=["RPL603"])
        assert rules_of(out) == ["RPL603"]
        assert "planner" in out[0].message

    def test_spec_in_owner_module_is_clean(self):
        out = lint("""
            from jax.sharding import PartitionSpec as P
            SPEC = P("nodes")
            """, path="src/repro/core/planner.py", only=["RPL603"])
        assert out == []

    def test_spec_shipped_with_mesh_op_is_clean(self):
        out = lint("""
            import jax
            from jax.sharding import NamedSharding, PartitionSpec as P
            def place(x, mesh):
                return jax.device_put(x, NamedSharding(mesh, P("nodes")))
            """, path="src/repro/core/x.py", only=["RPL603"])
        assert out == []

    def test_spec_shipped_via_local_name_is_clean(self):
        """bpt_trainer idiom: batch_spec = P("nodes") referenced by the
        shard_map in_specs ships the spec."""
        out = lint("""
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            def build(body, mesh):
                batch_spec = P("nodes")
                return shard_map(body, mesh=mesh,
                                 in_specs=(batch_spec,), out_specs=P())
            """, path="src/repro/core/x.py", only=["RPL603"])
        assert out == []

    def test_spec_axis_outside_vocabulary_flags(self):
        out = lint("""
            from jax.sharding import NamedSharding, PartitionSpec as P
            def place(mesh):
                return NamedSharding(mesh, P("bogus"))
            """, path="src/repro/launch/sharding.py", only=["RPL603"])
        assert rules_of(out) == ["RPL603"]
        assert "bogus" in out[0].message

    def test_dynamic_spec_is_skipped(self):
        out = lint("""
            from jax.sharding import PartitionSpec as P
            def spec_for(axes):
                return P(*axes)
            EMPTY = P()
            """, path="src/repro/core/x.py", only=["RPL603"])
        assert out == []

    def test_unregistered_dataclass_in_traced_code_flags(self):
        out = lint("""
            import dataclasses, jax
            @dataclasses.dataclass
            class Cache:
                x: int
            @jax.jit
            def step(a):
                return Cache(a)
            """, path="src/repro/models/c.py", only=["RPL604"])
        assert rules_of(out) == ["RPL604"]
        assert "Cache" in out[0].message

    def test_registered_dataclass_is_clean(self):
        out = lint("""
            import dataclasses, jax
            @dataclasses.dataclass
            class Cache:
                x: int
            jax.tree_util.register_dataclass(Cache)
            @jax.jit
            def step(a):
                return Cache(a)
            """, path="src/repro/models/c.py", only=["RPL604"])
        assert out == []

    def test_untraced_dataclass_construction_is_clean(self):
        out = lint("""
            import dataclasses
            @dataclasses.dataclass
            class Report:
                x: int
            def summarize(a):
                return Report(a)
            """, path="src/repro/models/c.py", only=["RPL604"])
        assert out == []

    def test_pallas_in_shardmap_without_check_rep_flags(self):
        out = lint("""
            from jax.experimental.shard_map import shard_map
            from jax.experimental import pallas as pl
            def body(x):
                return pl.pallas_call(kern, out_shape=None)(x)
            sm = shard_map(body, mesh=m, in_specs=None, out_specs=None)
            """, path="src/repro/models/k.py", only=["RPL605"])
        assert rules_of(out) == ["RPL605"]
        assert "check_rep" in out[0].message

    def test_pallas_in_shardmap_with_check_rep_false_is_clean(self):
        out = lint("""
            from jax.experimental.shard_map import shard_map
            from jax.experimental import pallas as pl
            def body(x):
                return pl.pallas_call(kern, out_shape=None)(x)
            sm = shard_map(body, mesh=m, in_specs=None, out_specs=None,
                           check_rep=False)
            """, path="src/repro/models/k.py", only=["RPL605"])
        assert out == []

    def test_pallas_free_shardmap_needs_no_check_rep(self):
        out = lint("""
            from jax.experimental.shard_map import shard_map
            def body(x):
                return x + 1
            sm = shard_map(body, mesh=m, in_specs=None, out_specs=None)
            """, path="src/repro/models/k.py", only=["RPL605"])
        assert out == []

    def test_fixture_project_without_mesh_module_uses_default_axes(self):
        """In-memory projects with no launch/mesh.py fall back to the
        default axis vocabulary instead of crashing or flagging all."""
        out = lint_sources({"core/a.py": textwrap.dedent("""
            import jax
            def f(x):
                return jax.lax.psum(x, "nodes")
            """)}, only=["RPL601"])
        assert out == []

    def test_mesh_module_inside_fixture_project_wins(self):
        """A fixture project that carries its own launch/mesh.py defines
        the vocabulary — cross-FILE resolution inside lint_sources."""
        out = lint_sources({
            "launch/mesh.py": 'MESHES = {"m": ((2,), ("ring",))}\n',
            "core/a.py": textwrap.dedent("""
                import jax
                def f(x):
                    return jax.lax.psum(x, "nodes")
                """),
        }, only=["RPL601"])
        assert rules_of(out) == ["RPL601"]
        assert "ring" in out[0].message


# ----------------------------------------------------------------------
# the repo itself + the CLI
# ----------------------------------------------------------------------
class TestRepoIsClean:
    def test_repo_lints_clean(self):
        """The acceptance bar for the whole PR: the tree carries zero
        unsuppressed findings across every rule."""
        findings = lint_paths(
            [str(REPO / d) for d in ("src", "tests", "benchmarks")
             if (REPO / d).exists()])
        assert findings == [], "\n".join(f.format() for f in findings)

    def test_cli_json_and_exit_codes(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("def f(tc):\n    return tc.fused_outer\n")
        proc = subprocess.run(
            [sys.executable, "-m", "tools.reprolint", str(bad),
             "--format", "json", "--json-report",
             str(tmp_path / "report.json")],
            cwd=REPO, capture_output=True, text=True, timeout=120)
        assert proc.returncode == 1
        payload = json.loads(proc.stdout)
        assert payload["by_rule"] == {"RPL101": 1}
        report = json.loads((tmp_path / "report.json").read_text())
        assert report["findings"][0]["rule"] == "RPL101"

        good = tmp_path / "good.py"
        good.write_text("x = 1\n")
        proc = subprocess.run(
            [sys.executable, "-m", "tools.reprolint", str(good)],
            cwd=REPO, capture_output=True, text=True, timeout=120)
        assert proc.returncode == 0
        assert "0 findings" in proc.stdout

    def test_cli_only_and_disable_flags(self, tmp_path):
        """--only narrows the rule set, --disable carves rules out of it,
        and the JSON report carries zero-inclusive per-rule counts for
        exactly the rules that RAN."""
        bad = tmp_path / "bad.py"
        # trips RPL101 (config read) AND RPL601 (bogus collective axis)
        bad.write_text(
            "import jax\n"
            "def f(tc, x):\n"
            "    if tc.fused_outer:\n"
            "        return jax.lax.psum(x, 'banana')\n")

        proc = subprocess.run(
            [sys.executable, "-m", "tools.reprolint", str(bad),
             "--only", "RPL101,RPL601", "--disable", "RPL101",
             "--format", "json"],
            cwd=REPO, capture_output=True, text=True, timeout=120)
        assert proc.returncode == 1
        payload = json.loads(proc.stdout)
        assert payload["by_rule"] == {"RPL601": 1}
        # per-rule counts: RPL601 ran and found; RPL101 was disabled so
        # it has NO entry (absent != zero)
        assert payload["rules"] == {
            "RPL601": {"name": "collective-axis-unbound", "findings": 1}}

        # symbolic names work too, and a disabled-to-clean run exits 0
        proc = subprocess.run(
            [sys.executable, "-m", "tools.reprolint", str(bad),
             "--disable", "dispatch-train,collective-axis-unbound",
             "--format", "json"],
            cwd=REPO, capture_output=True, text=True, timeout=120)
        assert proc.returncode == 0
        payload = json.loads(proc.stdout)
        assert payload["findings"] == []
        assert "RPL101" not in payload["rules"]
        assert payload["rules"]["RPL605"]["findings"] == 0

        # unknown rule names are usage errors (exit 2), not silent no-ops
        proc = subprocess.run(
            [sys.executable, "-m", "tools.reprolint", str(bad),
             "--disable", "RPL999"],
            cwd=REPO, capture_output=True, text=True, timeout=120)
        assert proc.returncode == 2
        assert "RPL999" in proc.stderr
