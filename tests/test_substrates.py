"""Optimizers, data pipeline, checkpointing, CNN."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import checkpoint
from repro.data.pipeline import IDPADataset, host_batch, pack_sequences
from repro.data.synthetic import image_dataset, lm_corpus
from repro.models.cnn import (CNNConfig, cnn_accuracy, cnn_forward, cnn_loss,
                              init_cnn, make_case)
from repro.optim.optimizers import (apply_updates, clip_by_global_norm,
                                    global_norm, make_optimizer,
                                    warmup_cosine)


class TestOptimizers:
    @pytest.mark.parametrize("name", ["sgd", "momentum", "adamw"])
    def test_converges_on_quadratic(self, name):
        opt = make_optimizer(name)
        params = {"w": jnp.array([5.0, -3.0])}
        st_ = opt.init(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2)

        for _ in range(200):
            g = jax.grad(loss)(params)
            upd, st_ = opt.update(g, st_, params, 0.05)
            params = apply_updates(params, upd)
        assert float(loss(params)) < 1e-2

    def test_clip(self):
        g = {"a": jnp.ones((100,)) * 10}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)
        assert float(norm) == pytest.approx(100.0, rel=1e-4)

    def test_schedule(self):
        s = warmup_cosine(1.0, 10, 100)
        assert float(s(0)) == 0.0
        assert float(s(10)) == pytest.approx(1.0)
        assert float(s(100)) == pytest.approx(0.1, rel=1e-3)
        assert float(s(5)) == pytest.approx(0.5)


class TestData:
    def test_pack_sequences(self):
        corpus = np.arange(101, dtype=np.int32)
        rows = pack_sequences(corpus, 10)
        assert rows.shape == (10, 11)
        b = host_batch(rows[:2])
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])

    def test_pack_sequences_short_corpus_raises_clearly(self):
        """Regression: a corpus shorter than one row used to die inside
        np.stack with an opaque shape error."""
        with pytest.raises(ValueError, match="too short .* seq_len"):
            pack_sequences(np.arange(5, dtype=np.int32), 10)
        # boundary: exactly one row packs fine
        rows = pack_sequences(np.arange(11, dtype=np.int32), 10)
        assert rows.shape == (1, 11)

    def test_pack_sequences_bad_seq_len_raises(self):
        with pytest.raises(ValueError, match="seq_len"):
            pack_sequences(np.arange(10, dtype=np.int32), 0)

    def test_corpus_learnable(self):
        c = lm_corpus(5000, 256, seed=0)
        assert c.min() >= 0 and c.max() < 256
        # Markov structure: conditional entropy < marginal entropy
        from collections import Counter
        pairs = Counter(zip(c[:-1], c[1:], strict=True))
        marg = Counter(c)
        n = len(c) - 1
        h_joint = -sum(v / n * np.log(v / n) for v in pairs.values())
        h_marg = -sum(v / len(c) * np.log(v / len(c)) for v in marg.values())
        assert h_joint - h_marg < h_marg  # H(X2|X1) < H(X)

    def test_idpa_dataset_views(self):
        xs = np.arange(1000)
        ds = IDPADataset({"x": xs}, num_nodes=4, batches=2,
                         frequencies=[1, 1, 2, 2])
        views = ds.node_views()
        assert len(views) == 4
        total = ds.totals.sum()
        assert total == 500                      # first batch released
        ds.report_durations([1.0, 1.0, 0.5, 0.5])
        assert ds.totals.sum() == 1000
        rng = np.random.default_rng(0)
        b = ds.node_batch(2, 16, rng)
        assert b["x"].shape == (16,)

    def test_image_dataset_signal(self):
        xs, ys = image_dataset(200, size=16)
        assert xs.shape == (200, 16, 16, 3)
        # class signal: same-class images correlate more than cross-class
        c0 = xs[ys == 0]
        c1 = xs[ys == 1]
        if len(c0) > 2 and len(c1) > 2:
            within = np.mean([np.corrcoef(c0[0].ravel(), c0[i].ravel())[0, 1]
                              for i in range(1, min(4, len(c0)))])
            across = np.mean([np.corrcoef(c0[0].ravel(), c1[i].ravel())[0, 1]
                              for i in range(min(3, len(c1)))])
            assert within > across


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                          "b": jnp.ones((3,))},
                "scale": jnp.float32(2.5)}
        p = checkpoint.save(str(tmp_path), tree, step=7)
        assert os.path.exists(p)
        restored, step = checkpoint.restore(str(tmp_path), tree)
        assert step == 7
        np.testing.assert_array_equal(restored["layer"]["w"],
                                      tree["layer"]["w"])

    def test_latest_step(self, tmp_path):
        tree = {"w": jnp.zeros(2)}
        checkpoint.save(str(tmp_path), tree, step=1)
        checkpoint.save(str(tmp_path), tree, step=5)
        assert checkpoint.latest_step(str(tmp_path)) == 5

    def test_missing_key_raises(self, tmp_path):
        checkpoint.save(str(tmp_path), {"w": jnp.zeros(2)}, step=0)
        with pytest.raises(KeyError):
            checkpoint.restore(str(tmp_path), {"w": jnp.zeros(2),
                                               "extra": jnp.zeros(1)})


class TestCNN:
    def test_table2_cases(self):
        for case in ("case1", "case4", "case7"):
            cfg = make_case(case, image_size=32)
            params = init_cnn(jax.random.PRNGKey(0), cfg)
            assert len(params["conv"]) == cfg.conv_layers
            assert len(params["fc"]) == cfg.fc_layers
            x = jnp.zeros((2, 32, 32, 3))
            out = cnn_forward(params, x, cfg)
            assert out.shape == (2, cfg.num_classes)

    def test_one_step_improves_loss(self):
        cfg = CNNConfig(name="t", image_size=16, conv_layers=2, filters=4,
                        fc_layers=2, fc_neurons=32)
        xs, ys = image_dataset(64, size=16)
        batch = {"images": jnp.asarray(xs), "labels": jnp.asarray(ys)}
        params = init_cnn(jax.random.PRNGKey(0), cfg)
        loss0, g = jax.value_and_grad(lambda p: cnn_loss(p, batch, cfg))(params)
        params2 = jax.tree_util.tree_map(lambda p, gg: p - 0.5 * gg, params, g)
        loss1 = cnn_loss(params2, batch, cfg)
        assert float(loss1) < float(loss0)

    def test_accuracy_metric(self):
        cfg = CNNConfig(name="t", image_size=16, conv_layers=1, filters=4,
                        fc_layers=1, fc_neurons=16)
        params = init_cnn(jax.random.PRNGKey(0), cfg)
        xs, ys = image_dataset(32, size=16)
        acc = cnn_accuracy(params, {"images": jnp.asarray(xs),
                                    "labels": jnp.asarray(ys)}, cfg)
        assert 0.0 <= float(acc) <= 1.0
