"""Docs stay linked: the tier-1 mirror of the CI docs link-check job."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_required_docs_exist():
    for rel in ("docs/ARCHITECTURE.md", "docs/KERNELS.md", "README.md"):
        assert (REPO / rel).is_file(), f"missing {rel}"


def test_readme_links_docs():
    readme = (REPO / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/KERNELS.md" in readme


def test_no_dangling_intra_repo_links():
    proc = subprocess.run(
        [sys.executable, "tools/check_links.py", "README.md", "docs",
         "ROADMAP.md"],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 0, f"\n{proc.stdout}\n{proc.stderr}"
