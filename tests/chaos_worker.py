"""Training worker for the SIGKILL chaos test (tests/test_chaos.py).

Runs a small deterministic fused-SGWU training job, checkpointing params
AND resumable train state after every merge event, printing ``EVENT n``
after each event so the parent can kill it mid-run.  ``--resume`` restores
the latest state checkpoint first — a killed run relaunched with the same
command line continues losslessly.  The final merged weights are published
as step ``FINAL_STEP`` so the parent can compare runs.

Not a test file: invoked as ``python tests/chaos_worker.py`` by
test_chaos.py (and usable by hand for debugging).
"""
import argparse

import jax

FINAL_STEP = 10_000


def build_trainer(nodes: int, seed: int = 0):
    import numpy as np  # noqa: F401  (kept local: worker stays import-light)
    from repro.core.bpt_trainer import BPTTrainer
    from repro.core.types import TrainConfig
    from repro.data.pipeline import IDPADataset
    from repro.data.synthetic import image_dataset
    from repro.models.cnn import CNNConfig, cnn_loss, init_cnn

    cfg = CNNConfig(name="chaos", image_size=8, conv_layers=1, filters=4,
                    fc_layers=1, fc_neurons=32)
    xs, ys = image_dataset(64 * nodes * 2, size=8, seed=0)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    # batches=1: the allocation is settled up front, so the only inter-run
    # nondeterminism (measured durations feeding IDPA) is out of play and
    # the resumed trajectory must be BIT-identical to the uninterrupted one
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=nodes,
                     batches=1)
    tc = TrainConfig(outer_nodes=nodes, outer_strategy="sgwu",
                     fused_outer=True, optimizer="adamw",
                     learning_rate=2e-3, total_steps=100, warmup_steps=5,
                     local_steps=2, seed=seed)
    return BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}), params, ds,
                      tc, batch_size=16)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    from repro.checkpointing import checkpoint
    from repro.core.bpt_trainer import TrainHooks

    tr = build_trainer(args.nodes, seed=args.seed)
    hooks = TrainHooks(checkpoint_every=1, checkpoint_dir=args.ckpt_dir,
                       resume=args.resume)
    last = None
    for ev in tr.run(args.rounds, hooks):
        last = ev
        # the checkpoint for this event is already on disk (run() saves
        # before yielding) — the parent may SIGKILL us any time after this
        print(f"EVENT {ev.round}", flush=True)
    checkpoint.save(args.ckpt_dir, last.params, step=FINAL_STEP)
    print("DONE", flush=True)


if __name__ == "__main__":
    main()
