"""Model-layer correctness: chunked attention, SSD, MoE, decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import ModelConfig
from repro.kernels import ref
from repro.models import lm
from repro.models.attention import chunked_attention
from repro.models.mamba import ssd_chunked, ssd_reference
from repro.models.moe import init_moe, moe_layer


class TestChunkedAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("window", [None, 13])
    @pytest.mark.parametrize("chunks", [(16, 16), (32, 64), (1000, 1000)])
    def test_matches_naive(self, causal, window, chunks):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (2, 75, 4, 16))
        k = jax.random.normal(ks[1], (2, 75, 2, 16))
        v = jax.random.normal(ks[2], (2, 75, 2, 16))
        got = chunked_attention(q, k, v, causal=causal, window=window,
                                q_chunk=chunks[0], k_chunk=chunks[1])
        want = ref.attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)

    def test_traced_window_equals_static(self):
        """window passed as traced scalar (scan-over-layers pattern)."""
        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (1, 64, 2, 16))
        k = jax.random.normal(ks[1], (1, 64, 2, 16))
        v = jax.random.normal(ks[2], (1, 64, 2, 16))
        f = jax.jit(lambda w: chunked_attention(q, k, v, causal=True,
                                                window=w, q_chunk=16,
                                                k_chunk=16))
        got = f(jnp.int32(9))
        want = ref.attention_ref(q, k, v, causal=True, window=9)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)

    def test_grad_flows(self):
        key = jax.random.PRNGKey(2)
        q = jax.random.normal(key, (1, 32, 2, 8))
        g = jax.grad(lambda q_: chunked_attention(
            q_, q_[:, :, :2], q_[:, :, :2], q_chunk=8, k_chunk=8).sum())(q)
        assert float(jnp.abs(g).sum()) > 0


class TestSSD:
    @pytest.mark.parametrize("L,chunk", [(64, 16), (130, 32), (100, 256)])
    def test_chunked_matches_recurrence(self, L, chunk):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 5)
        b, H, P, N = 2, 3, 8, 16
        x = jax.random.normal(ks[0], (b, L, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)))
        B = jax.random.normal(ks[3], (b, L, N))
        C = jax.random.normal(ks[4], (b, L, N))
        D = jnp.ones((H,))
        got = ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
        want = ssd_reference(x, dt, A, B, C, D)
        np.testing.assert_allclose(got, want, atol=2e-4, rtol=1e-3)

    def test_state_decay_property(self):
        """With strongly negative A, distant history is forgotten: output at
        position t depends only weakly on inputs << t."""
        key = jax.random.PRNGKey(1)
        b, L, H, P, N = 1, 64, 2, 4, 8
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, L, H, P))
        dt = jnp.ones((b, L, H)) * 2.0
        A = -jnp.ones((H,)) * 8.0            # fast decay
        B = jax.random.normal(ks[3], (b, L, N))
        C = jax.random.normal(ks[4], (b, L, N))
        D = jnp.zeros((H,))
        y1 = ssd_chunked(x, dt, A, B, C, D, chunk=16)
        x2 = x.at[:, :8].set(jax.random.normal(ks[1], (b, 8, H, P)) * 10)
        y2 = ssd_chunked(x2, dt, A, B, C, D, chunk=16)
        np.testing.assert_allclose(y1[:, 32:], y2[:, 32:], atol=1e-3)


class TestMoE:
    def _cfg(self, E=8, k=2):
        return ModelConfig(name="m", arch_type="moe", num_layers=1,
                           d_model=32, num_heads=2, num_kv_heads=2,
                           head_dim=16, vocab_size=64, num_experts=E,
                           top_k=k, expert_d_ff=16)

    def test_no_drop_with_big_capacity(self):
        """With capacity >= S*k the layer equals the dense top-k compute."""
        cfg = self._cfg()
        key = jax.random.PRNGKey(0)
        p = init_moe(key, cfg.d_model, cfg.num_experts, cfg.expert_d_ff)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        out, aux = moe_layer(p, x, cfg, capacity_factor=8.0)

        # dense reference: every token through its top-k experts
        logits = x @ p["router"]["w"]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
        top_p = top_p / top_p.sum(-1, keepdims=True)
        want = jnp.zeros_like(x)
        for e in range(cfg.num_experts):
            h = jax.nn.silu(x @ p["wg"][e]) * (x @ p["wi"][e])
            y = h @ p["wo"][e]
            for kk in range(cfg.top_k):
                sel = (top_e[..., kk] == e).astype(x.dtype) * top_p[..., kk]
                want = want + sel[..., None] * y
        np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-3)

    def test_capacity_drops_dont_crash_and_bound_output(self):
        cfg = self._cfg()
        p = init_moe(jax.random.PRNGKey(0), cfg.d_model, cfg.num_experts,
                     cfg.expert_d_ff)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
        out, aux = moe_layer(p, x, cfg, capacity_factor=0.5)
        assert out.shape == x.shape
        assert not bool(jnp.isnan(out).any())

    def test_aux_loss_near_one_for_uniform_router(self):
        """Switch aux loss == E * sum f*p -> ~1 when routing is uniform."""
        cfg = self._cfg(E=4, k=1)
        p = init_moe(jax.random.PRNGKey(0), cfg.d_model, 4, 16)
        p["router"]["w"] = jnp.zeros_like(p["router"]["w"])  # uniform
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 128, cfg.d_model))
        _, aux = moe_layer(p, x, cfg)
        assert 0.9 < float(aux) < 1.2

    def test_grad_flows_to_experts_and_router(self):
        cfg = self._cfg()
        p = init_moe(jax.random.PRNGKey(0), cfg.d_model, cfg.num_experts,
                     cfg.expert_d_ff)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))

        def f(p_):
            out, aux = moe_layer(p_, x, cfg)
            return (out ** 2).sum() + aux
        g = jax.grad(f)(p)
        assert float(jnp.abs(g["router"]["w"]).sum()) > 0
        assert float(jnp.abs(g["wi"]).sum()) > 0


def _tiny(arch_type="dense", **kw):
    base = dict(name="t", arch_type=arch_type, num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=128)
    base.update(kw)
    return ModelConfig(**base)


class TestDecodeConsistency:
    """decode_step against a teacher-forced forward (the serving invariant)."""

    @pytest.mark.parametrize("cfg", [
        _tiny("dense"),
        _tiny("dense", sliding_window=8, window_pattern=2),
        _tiny("moe", num_experts=4, top_k=2, expert_d_ff=64,
              moe_capacity_factor=8.0),   # dropless so decode == forward
        _tiny("ssm", num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0,
              ssm_heads=4, ssm_head_dim=16, ssm_state=8),
        _tiny("hybrid", ssm_heads=4, ssm_head_dim=16, ssm_state=8),
    ], ids=["dense", "windowed", "moe", "ssm", "hybrid"])
    def test_decode_matches_forward(self, cfg):
        key = jax.random.PRNGKey(0)
        params = lm.init_params(key, cfg)
        S = 12
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                                  cfg.vocab_size)
        hidden, _, _ = lm.forward(params, toks, cfg)
        table = params.get("lm_head", params["embed"])["table"]
        want = hidden[:, -1] @ table.astype(hidden.dtype).T

        cache = lm.init_cache(2, S + 1, cfg)
        logits = None
        for i in range(S):
            logits, cache = lm.decode_step(params, cache, jnp.int32(i),
                                           toks[:, i:i + 1], cfg)
        got = logits[:, 0]
        if cfg.final_softcap:
            want = jnp.tanh(want / cfg.final_softcap) * cfg.final_softcap
        np.testing.assert_allclose(
            got, want.astype(jnp.float32),
            atol=0.15, rtol=0.1)  # bf16 activations accumulate error


class TestCNNShapes:
    """Table-2 case shapes + the pool_every knob (no longer dead config)."""

    @pytest.mark.parametrize("case", ["case1", "case2", "case3", "case4",
                                      "case5", "case6", "case7"])
    def test_table2_case_forward_shape(self, case):
        from repro.models.cnn import cnn_forward, init_cnn, make_case
        cfg = make_case(case)
        params = init_cnn(jax.random.PRNGKey(0), cfg)
        assert len(params["conv"]) == cfg.conv_layers
        assert len(params["fc"]) == cfg.fc_layers
        images = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
        logits = cnn_forward(params, images, cfg)
        assert logits.shape == (1, cfg.num_classes)
        assert np.isfinite(np.asarray(logits)).all()

    def test_pool_every_controls_pooling_cadence(self):
        """pool_every=k pools after every k-th conv (while >= 8 px): the
        classifier input size must follow the knob, not a hidden heuristic."""
        from repro.models.cnn import CNNConfig, cnn_forward, init_cnn
        base = dict(image_size=32, conv_layers=4, filters=4, fc_layers=1,
                    fc_neurons=16, num_classes=10)
        every1 = CNNConfig(name="p1", **base)                 # default
        every2 = CNNConfig(name="p2", pool_every=2, **base)
        # every layer: 32->16->8->4, layer 4 at 4 px skips -> d_in 4*4*4
        p1 = init_cnn(jax.random.PRNGKey(0), every1)
        assert p1["fc"][0]["w"].shape[0] == 4 * 4 * 4
        # every 2nd layer: pools after conv2 (32->16) and conv4 (16->8)
        p2 = init_cnn(jax.random.PRNGKey(0), every2)
        assert p2["fc"][0]["w"].shape[0] == 8 * 8 * 4
        images = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
        for cfg, params in ((every1, p1), (every2, p2)):
            assert cnn_forward(params, images, cfg).shape == (2, 10)

    def test_pool_every_must_be_positive(self):
        from repro.models.cnn import CNNConfig
        with pytest.raises(ValueError, match="pool_every"):
            CNNConfig(name="bad", pool_every=0)
