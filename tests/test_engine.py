"""Engine-layer suite: the resolve_engine matrix, the single-decision-point
guarantee, TrainConfig construction-time validation, and the streaming
RoundEvent API (early-stop, TrainHooks cadences, mid-run checkpointing).

Device-count-dependent expectations are keyed on the live device count —
the CI ``multidevice`` job re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so every branch of
the matrix (device engines AND their fallbacks) executes on every PR.
"""
import time
from pathlib import Path

import jax
import numpy as np
import pytest

import repro.core.engine as engine_module
from repro.checkpointing import checkpoint
from repro.core.bpt_trainer import BPTTrainer, TrainHooks
from repro.core.engine import (ENGINES, HeapDeviceEngine, HeapEngine,
                               ScanEngine, SequentialEngine, ShardMapEngine,
                               VmapEngine, engine_config, resolve_engine)
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.models.cnn import CNNConfig, cnn_accuracy, cnn_loss, init_cnn

NDEV = len(jax.devices())


def need_devices(m):
    return pytest.mark.skipif(
        NDEV < m, reason=f"needs {m} devices (have {NDEV}); run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _cfg(**kw):
    kw.setdefault("outer_nodes", 2)
    return TrainConfig(**kw)


def _make_trainer(m=2, eval_fn=False, batches=1, **tc_kwargs):
    cfg = CNNConfig(name="eng", image_size=8, conv_layers=1, filters=4,
                    fc_layers=1, fc_neurons=32)
    xs, ys = image_dataset(64 * m * 2, size=8, seed=0)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=m,
                     batches=batches)
    tc_kwargs.setdefault("outer_strategy", "sgwu")
    tc = TrainConfig(outer_nodes=m, optimizer="adamw", learning_rate=2e-3,
                     total_steps=100, warmup_steps=5, local_steps=2,
                     seed=0, **tc_kwargs)
    ef = None
    if eval_fn:
        import jax.numpy as jnp
        xe, ye = image_dataset(64, size=8, seed=9)
        eb = {"images": jnp.asarray(xe), "labels": jnp.asarray(ye)}
        ef = jax.jit(lambda p: cnn_accuracy(p, eb, cfg))
    return BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}), params, ds,
                      tc, batch_size=16, eval_fn=ef)


# ----------------------------------------------------------------------
# resolve_engine: the full flag matrix
# ----------------------------------------------------------------------
def _expected(strategy, fused, device, uneven, m, ndev):
    """Expected (backend, requested) or the ValueError the config earns."""
    if strategy == "sgwu":
        if device:
            return ("device", "device") if ndev >= m else ("vmap", "device")
        if fused:
            return ("vmap", "vmap")
        if uneven:
            return ValueError
        return ("sequential", "sequential")
    if uneven:
        return ValueError
    if strategy == "agwu":
        if device:
            return ("heap-device", "heap-device") if ndev >= m \
                else ("heap", "heap-device")
        return ("heap", "heap")
    return ("scan", "scan")


MATRIX = [(s, f, d, u)
          for s in ("sgwu", "agwu", "sync")
          for f in (True, False)
          for d in (True, False)
          for u in (True, False)]


class TestResolveMatrix:
    @pytest.mark.parametrize("strategy,fused,device,uneven", MATRIX)
    @pytest.mark.parametrize("m", [2, 8])
    def test_every_combination(self, strategy, fused, device, uneven, m):
        cfg = _cfg(outer_strategy=strategy, fused_outer=fused,
                   device_outer=device, uneven_batches=uneven,
                   outer_nodes=m)
        want = _expected(strategy, fused, device, uneven, m, NDEV)
        if want is ValueError:
            with pytest.raises(ValueError, match="uneven"):
                resolve_engine(cfg)
            return
        backend, requested = want
        plan = resolve_engine(cfg)
        assert plan.backend == backend
        assert plan.requested == requested
        assert plan.engine_cls is ENGINES[backend]
        assert plan.strategy == strategy
        # the fallback is RECORDED exactly when the request was downgraded
        assert bool(plan.fallback) == (backend != requested)
        if plan.backend == "device":
            assert plan.mesh is not None \
                and plan.mesh.shape["nodes"] == m
        else:
            assert plan.mesh is None

    def test_forced_fallback_always(self):
        """m > device count: both device requests downgrade, with the
        reason recorded in the plan (runs identically on any host)."""
        m = 2 * NDEV
        plan = resolve_engine(_cfg(outer_strategy="sgwu", device_outer=True,
                                   outer_nodes=m))
        assert (plan.backend, plan.requested) == ("vmap", "device")
        assert str(m) in plan.fallback and "vmap" in plan.fallback
        plan = resolve_engine(_cfg(outer_strategy="agwu", device_outer=True,
                                   outer_nodes=m))
        assert (plan.backend, plan.requested) == ("heap", "heap-device")
        assert plan.fallback

    def test_explicit_device_injection(self):
        """resolve_engine decides against the devices it is HANDED."""
        one = jax.devices()[:1]
        plan = resolve_engine(_cfg(outer_strategy="agwu", device_outer=True),
                              devices=one)
        assert (plan.backend, plan.requested) == ("heap", "heap-device")
        plan = resolve_engine(_cfg(outer_strategy="sgwu", device_outer=True),
                              devices=one)
        assert (plan.backend, plan.requested) == ("vmap", "device")

    def test_single_node_device_resolves_anywhere(self):
        """m=1 fits any backend: the device engine runs even on 1 device."""
        plan = resolve_engine(_cfg(outer_strategy="sgwu", device_outer=True,
                                   outer_nodes=1))
        assert plan.backend == "device" and plan.engine_cls is ShardMapEngine

    @need_devices(2)
    def test_named_nodes_mesh(self):
        plan = resolve_engine(_cfg(outer_strategy="sgwu", device_outer=True,
                                   mesh_name="nodes2"))
        assert plan.backend == "device"
        assert plan.mesh.shape == {"nodes": 2}

    def test_mesh_without_nodes_axis(self):
        """A mesh_name with no `nodes` axis is a config BUG (raise), unless
        the mesh cannot even be built (capacity -> transparent fallback)."""
        cfg = _cfg(outer_strategy="sgwu", device_outer=True,
                   mesh_name="tiny")
        if NDEV >= 4:           # tiny = (2,2)(data,model): builds, no nodes
            with pytest.raises(ValueError, match="nodes"):
                resolve_engine(cfg)
        else:
            assert resolve_engine(cfg).backend == "vmap"

    @need_devices(4)
    def test_mesh_nodes_axis_size_mismatch(self):
        with pytest.raises(ValueError, match="nodes"):
            resolve_engine(_cfg(outer_strategy="sgwu", device_outer=True,
                                mesh_name="nodes4", outer_nodes=2))

    @pytest.mark.parametrize("name", sorted(ENGINES))
    def test_engine_config_roundtrip(self, name):
        """TrainConfig(**engine_config(name)) resolves to the named engine
        (modulo the documented device-count fallback)."""
        plan = resolve_engine(TrainConfig(**engine_config(
            name, outer_nodes=2)))
        assert plan.requested == name
        if NDEV >= 2 or name not in ("device", "heap-device"):
            assert plan.backend == name and plan.engine_cls is ENGINES[name]
        else:
            assert plan.fallback

    def test_engine_config_unknown_name(self):
        with pytest.raises(ValueError, match="unknown engine"):
            engine_config("warp")


class TestSingleDecisionPoint:
    def test_only_resolve_engine_reads_the_flags(self):
        """Linter-verifiable acceptance bar: no module under src/repro
        other than core/engine.py reads the fused_outer / device_outer /
        mesh_name substrate flags off a config object.  Asserted through
        reprolint's AST pass (rule RPL101), which the old raw-source
        regex grew into — attribute reads are matched on the tree (no
        hits inside strings/comments, multi-line receivers still match)
        and ``getattr(cfg, "fused_outer")`` is caught too."""
        from tools.reprolint import lint_paths
        root = Path(engine_module.__file__).parents[1]   # src/repro
        offenders = [
            f"{Path(f.path).relative_to(root)}:{f.line}"
            for f in lint_paths([str(root)], only=["RPL101"])
        ]
        assert not offenders, (
            "substrate flags must only be inspected by "
            f"engine.resolve_engine, found: {offenders}")


class TestTrainConfigValidation:
    def test_bad_strategy(self):
        with pytest.raises(ValueError, match="outer_strategy"):
            TrainConfig(outer_strategy="ring")

    def test_bad_partitioning(self):
        with pytest.raises(ValueError, match="partitioning"):
            TrainConfig(partitioning="static")

    def test_bad_optimizer(self):
        with pytest.raises(ValueError, match="optimizer"):
            TrainConfig(optimizer="lion")

    def test_bad_counts(self):
        with pytest.raises(ValueError, match="outer_nodes"):
            TrainConfig(outer_nodes=0)
        with pytest.raises(ValueError, match="local_steps"):
            TrainConfig(local_steps=0)

    def test_valid_choices_construct(self):
        for s in ("sgwu", "agwu", "sync"):
            assert TrainConfig(outer_strategy=s).outer_strategy == s


# ----------------------------------------------------------------------
# backend + fallback surfaced on TrainReport
# ----------------------------------------------------------------------
class TestReportSurface:
    @pytest.mark.parametrize("name", ["scan", "sequential", "vmap", "heap"])
    def test_backend_recorded(self, name):
        tr = _make_trainer(m=2, **engine_config(name))
        rep = tr.train(rounds=2)
        assert rep.backend == name
        assert rep.fallback == ""
        assert "fallback" not in rep.summary()

    @pytest.mark.parametrize("name", ["device", "heap-device"])
    @need_devices(2)
    def test_device_backends_recorded(self, name):
        tr = _make_trainer(m=2, **engine_config(name))
        rep = tr.train(rounds=2)
        assert rep.backend == name and rep.fallback == ""

    def test_fallback_surfaced(self):
        m = 2 * NDEV
        tr = _make_trainer(m=m, **engine_config("device"))
        rep = tr.train(rounds=1)
        assert rep.backend == "vmap"
        assert str(m) in rep.fallback
        assert rep.summary()["fallback"] == rep.fallback


# ----------------------------------------------------------------------
# streaming API: RoundEvent, TrainHooks, early-stop, checkpoint resume
# ----------------------------------------------------------------------
class TestStreaming:
    def test_sgwu_event_stream(self):
        tr = _make_trainer(m=2)
        events = list(tr.run(3))
        assert [ev.round for ev in events] == [0, 1, 2]
        for ev in events:
            assert ev.node_losses.shape == (2,)
            assert np.isfinite(ev.loss)
            assert ev.params is not None
        # virtual clock and comm volume are cumulative and monotone
        clocks = [ev.virtual_clock for ev in events]
        comms = [ev.comm_bytes for ev in events]
        assert clocks == sorted(clocks) and comms == sorted(comms)
        assert comms[0] > 0

    def test_agwu_event_stream_is_per_push(self):
        tr = _make_trainer(m=2, **engine_config("heap"))
        events = list(tr.run(2))
        assert len(events) == 4                      # m x rounds pushes
        assert sorted({ev.node for ev in events}) == [0, 1]
        assert [ev.round for ev in events] == [0, 1, 2, 3]

    def test_stream_matches_train_report(self):
        """run() and train() are the same computation on a fixed seed."""
        streamed = [ev.loss for ev in _make_trainer(m=2).run(3)]
        report = _make_trainer(m=2).train(rounds=3)
        np.testing.assert_allclose(streamed, report.losses, rtol=1e-6)

    def test_on_round_hook_and_eval_cadence(self):
        tr = _make_trainer(m=2, eval_fn=True)
        seen = []
        hooks = TrainHooks(on_round=seen.append, eval_every=2)
        events = list(tr.run(4, hooks))
        assert seen == events
        assert [ev.accuracy is not None for ev in events] == \
            [False, True, False, True]

    def test_default_eval_cadences(self):
        # SGWU: every round; sync scan: every 5 rounds; AGWU: every m pushes
        sg = _make_trainer(m=2, eval_fn=True).train(rounds=2)
        assert len(sg.accuracies) == 2
        sc = _make_trainer(m=1, eval_fn=True, **engine_config("scan"))\
            .train(rounds=5)
        assert len(sc.accuracies) == 1
        ag = _make_trainer(m=2, eval_fn=True, **engine_config("heap"))\
            .train(rounds=2)
        assert len(ag.accuracies) == 2               # 4 pushes / m=2

    @pytest.mark.parametrize("device", [
        False, pytest.param(True, marks=need_devices(2))])
    def test_early_stop_and_midrun_checkpoint_resume(self, tmp_path, device):
        """The acceptance bar, end to end under VmapEngine AND
        ShardMapEngine: stream rounds, checkpoint mid-run via TrainHooks,
        early-stop on a loss threshold, restore the checkpoint into a new
        trainer and keep training."""
        name = "device" if device else "vmap"
        tr = _make_trainer(m=2, **engine_config(name))
        ckpt = str(tmp_path / "ck")
        hooks = TrainHooks(checkpoint_every=2, checkpoint_dir=ckpt)
        max_rounds, threshold, events = 12, None, []
        for ev in tr.run(max_rounds, hooks):
            events.append(ev)
            if threshold is None:
                threshold = ev.loss          # first-round loss
            elif ev.loss < 0.995 * threshold:
                break                        # early-stop on the threshold
        assert tr.last_plan.engine_cls is \
            (ShardMapEngine if device else VmapEngine)
        assert 1 < len(events) < max_rounds          # genuinely stopped early
        # a mid-run checkpoint exists (every 2nd event, BEFORE the stop)
        step = checkpoint.latest_step(ckpt)
        assert step is not None and step <= len(events)
        restored, got = checkpoint.restore(ckpt, tr.params0)
        assert got == step
        # resume: a fresh trainer continues from the restored weights
        tr2 = _make_trainer(m=2, **engine_config(name))
        tr2.params0 = restored
        rep2 = tr2.train(rounds=2)
        assert np.isfinite(rep2.losses).all()
        # it continues from TRAINED weights, not from scratch
        assert rep2.losses[0] < 1.05 * threshold

    def test_generator_raises_bad_config_on_first_next(self):
        tr = _make_trainer(m=2, fused_outer=False, uneven_batches=True)
        with pytest.raises(ValueError, match="uneven"):
            next(iter(tr.run(1)))

    def test_break_stops_cleanly_and_rerun_works(self):
        tr = _make_trainer(m=2)
        for _ev in tr.run(5):
            break                            # caller walks away mid-stream
        rep = tr.train(rounds=2)             # the trainer is reusable
        assert len(rep.losses) == 2


class TestRoundWallClock:
    def test_stacked_round_excludes_data_prep(self, monkeypatch):
        """Regression: the Eq. 8 wall must start AFTER the host batch draw
        — a slow input pipeline must not inflate the virtual clock, the
        sync-wait, or the IDPA duration feedback."""
        tr = _make_trainer(m=2)
        orig = tr.dataset.stacked_round_batches
        delay = 0.2

        def slow_draw(*args, **kwargs):
            time.sleep(delay)
            return orig(*args, **kwargs)

        monkeypatch.setattr(tr.dataset, "stacked_round_batches", slow_draw)
        events = list(tr.run(2))
        # round 1 is compile-free: its clock increment is pure compute
        # wall and must exclude the injected data-prep delay entirely
        increment = events[1].virtual_clock - events[0].virtual_clock
        assert increment < delay

    def test_scan_round_excludes_data_prep(self, monkeypatch):
        tr = _make_trainer(m=1, **engine_config("scan"))
        orig = tr.dataset.node_batch
        delay = 0.1

        def slow_draw(*args, **kwargs):
            time.sleep(delay)
            return orig(*args, **kwargs)

        monkeypatch.setattr(tr.dataset, "node_batch", slow_draw)
        events = list(tr.run(2))
        increment = events[1].virtual_clock - events[0].virtual_clock
        # two local steps -> two slow draws per round, all excluded
        assert increment < 2 * delay


class TestEngineClasses:
    def test_registry_matches_backends(self):
        assert ENGINES == {"scan": ScanEngine, "sequential": SequentialEngine,
                           "vmap": VmapEngine, "device": ShardMapEngine,
                           "heap": HeapEngine, "heap-device": HeapDeviceEngine}
        for name, cls in ENGINES.items():
            assert cls.backend == name
