"""IDPA (Alg. 3.1) unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.idpa import (IDPAPartitioner, UDPAPartitioner,
                             effective_iterations, workload_balance_degree)


def drive(part, t):
    part.first_batch()
    while not part.done:
        part.next_batch(t * np.maximum(part.totals, 1))
    return part.totals


class TestEffectiveIterations:
    def test_eq6_formula(self):
        # K' = K + A/2 - 1 (paper Eq. 6, floored)
        assert effective_iterations(100, 10) == 104
        assert effective_iterations(10, 2) == 10    # 2 + (10 - 1) = 10 (floor)

    def test_bounds(self):
        with pytest.raises(ValueError):
            effective_iterations(10, 0)
        with pytest.raises(ValueError):
            effective_iterations(10, 11)

    def test_boundary_a_equals_k_rejected(self):
        """Paper requires A < K strictly; A == K must raise, A == K-1 is
        the largest legal allocation-batch count."""
        with pytest.raises(ValueError):
            effective_iterations(10, 10)
        assert effective_iterations(10, 9) == 10 + 9 // 2 - 1 + 1  # K+floor((A-1)/2)
        assert effective_iterations(2, 1) == 2


class TestIDPA:
    def test_first_batch_eq2(self):
        p = IDPAPartitioner(1000, 4, 2, frequencies=[1, 1, 1, 1])
        a = p.first_batch()
        assert a.sum() == 500 and np.all(a == 125)

    def test_first_batch_proportional(self):
        p = IDPAPartitioner(1000, 2, 2, frequencies=[1, 3])
        a = p.first_batch()
        assert a[0] == 125 and a[1] == 375           # floor + remainder

    def test_faster_nodes_get_more(self):
        t = np.array([2.0, 1.0, 0.5, 0.25])
        p = IDPAPartitioner(8000, 4, 4, frequencies=1 / t, mode="balanced")
        totals = drive(p, t)
        assert np.all(np.diff(totals) > 0)           # monotone in speed
        busy = t * totals
        assert workload_balance_degree(busy) > 0.95

    def test_balanced_beats_paper_mode_balance(self):
        t = np.array([2.0, 1.0, 0.5, 0.25, 0.125])
        res = {}
        for mode in ("paper", "balanced"):
            p = IDPAPartitioner(20000, 5, 5, frequencies=1 / t, mode=mode)
            totals = drive(p, t)
            res[mode] = workload_balance_degree(t * totals)
        assert res["balanced"] >= res["paper"]

    @settings(max_examples=50, deadline=None)
    @given(
        m=st.integers(2, 8),
        a=st.integers(1, 6),
        n_per=st.integers(50, 500),
        seed=st.integers(0, 100),
        mode=st.sampled_from(["paper", "balanced"]),
    )
    def test_invariants(self, m, a, n_per, seed, mode):
        """Every batch sums to floor(N/A); increments non-negative;
        totals == batch_size * A after driving."""
        rng = np.random.default_rng(seed)
        t = 0.25 + rng.random(m)
        N = n_per * m
        p = IDPAPartitioner(N, m, a, frequencies=1 / t, mode=mode)
        drive(p, t)
        b = N // a
        for alloc in p.history:
            assert alloc.sum() == b
            assert np.all(alloc >= 0)
        assert p.totals.sum() == b * a


class TestUDPA:
    def test_uniform(self):
        p = UDPAPartitioner(1200, 4, 3)
        p.allocate_all()
        assert np.all(p.totals == 300)


class TestBalanceDegree:
    def test_degenerate(self):
        assert workload_balance_degree([]) == 1.0
        assert workload_balance_degree([0, 0]) == 1.0

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.floats(0.1, 100), min_size=1, max_size=20))
    def test_range(self, loads):
        b = workload_balance_degree(loads)
        assert 0.0 < b <= 1.0


class TestChurnInvariants:
    """Node-churn extensions of Alg. 3.1: allocation under an active mask
    must stay proportional to measured power, cover each batch exactly
    once, hand a zero-capacity node zero work without starving the batch,
    and never migrate a dead node's existing stripe (§3.3.1)."""

    def test_first_batch_respects_active_mask(self):
        p = IDPAPartitioner(1200, 4, 2, frequencies=[1, 2, 1, 2])
        a = p.first_batch(active=[True, False, True, True])
        assert a[1] == 0 and a.sum() == 600
        # Eq. (2) over the surviving frequencies [1, 1, 2]
        assert (a[0], a[2], a[3]) == (150, 150, 300)

    def test_allocation_proportional_to_measured_power(self):
        p = IDPAPartitioner(4000, 4, 2, frequencies=np.ones(4))
        p.first_batch()
        # node 0 measures 2x the per-sample time of nodes 1-2; node 3 dead
        t = np.array([2.0, 1.0, 1.0, 1.0])
        inc = p.next_batch(t * np.maximum(p.totals, 1),
                           active=[True, True, True, False])
        assert inc[3] == 0
        assert inc[0] < inc[1]                       # slower => less work
        assert inc.sum() == 2000                     # batch fully covered

    def test_zero_capacity_node_gets_zero_without_starving(self):
        p = IDPAPartitioner(1000, 4, 2, frequencies=np.ones(4))
        p.first_batch()
        durs = np.array([1.0, np.inf, 1.0, 1.0]) * np.maximum(p.totals, 1)
        inc = p.next_batch(durs)
        assert inc[1] == 0
        assert inc.sum() == 500                      # batch still lands

    def test_dead_node_garbage_durations_ignored(self):
        # a dead node reports nothing; stale/garbage entries in its slot
        # must not affect validation or the allocation
        p = IDPAPartitioner(1000, 3, 2, frequencies=np.ones(3))
        p.first_batch()
        inc = p.next_batch([100.0, -1.0, 100.0],
                           active=[True, False, True])
        assert inc[1] == 0 and inc.sum() == 500

    def test_no_migration_dead_stripe_kept(self):
        p = IDPAPartitioner(1200, 3, 3, frequencies=np.ones(3))
        p.first_batch()
        stripe = int(p.totals[2])
        t = np.maximum(p.totals, 1).astype(float)
        p.next_batch(t, active=[True, True, False])
        assert p.totals[2] == stripe                 # kept, not migrated
        # rejoin: the node reports a real duration again and earns work
        inc = p.next_batch(np.maximum(p.totals, 1).astype(float))
        assert inc[2] > 0

    def test_active_mask_validation(self):
        p = IDPAPartitioner(1000, 4, 2, frequencies=np.ones(4))
        with pytest.raises(ValueError, match="active flag"):
            p.first_batch(active=[True, False])
        p2 = IDPAPartitioner(1000, 4, 2, frequencies=np.ones(4))
        with pytest.raises(ValueError, match="inactive"):
            p2.first_batch(active=np.zeros(4, dtype=bool))

    def test_all_carriers_infinite_raises(self):
        p = IDPAPartitioner(1000, 2, 2, frequencies=np.ones(2))
        p.first_batch()
        with pytest.raises(ValueError, match="carry"):
            p.next_batch([np.inf, np.inf])

    def test_udpa_active_mask(self):
        p = UDPAPartitioner(900, 3, 3)
        p.first_batch()
        a = p.next_batch(active=[True, False, True])
        assert a[1] == 0 and a.sum() == 300

    def test_state_round_trip_mid_churn(self):
        """Checkpoint/resume mid-churn: a reloaded partitioner produces
        the identical next allocation (crash-safe training state)."""
        p = IDPAPartitioner(2000, 4, 4, frequencies=[1, 2, 1, 2])
        p.first_batch()
        p.next_batch(np.maximum(p.totals, 1).astype(float),
                     active=[True, True, True, False])
        q = IDPAPartitioner(2000, 4, 4, frequencies=[1, 2, 1, 2])
        q.load_state_dict(p.state_dict())
        assert q.current_batch == p.current_batch
        np.testing.assert_array_equal(q.totals, p.totals)
        t = np.array([1.0, 0.5, 1.0, 0.5])
        a1 = p.next_batch(t * np.maximum(p.totals, 1))
        a2 = q.next_batch(t * np.maximum(q.totals, 1))
        np.testing.assert_array_equal(a1, a2)

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(2, 8),
        a=st.integers(2, 5),
        seed=st.integers(0, 500),
        mode=st.sampled_from(["paper", "balanced"]),
    )
    def test_batches_sum_exactly_under_random_churn(self, m, a, seed, mode):
        """Whatever the churn pattern, every allocation batch sums to
        exactly floor(N/A), increments are non-negative, and masked nodes
        receive nothing."""
        rng = np.random.default_rng(seed)
        N = 200 * m
        p = IDPAPartitioner(N, m, a, frequencies=1 + rng.random(m),
                            mode=mode)
        b = N // a
        p.first_batch()
        while not p.done:
            active = rng.random(m) > 0.3
            if not active.any():
                active[int(rng.integers(m))] = True
            durs = (0.2 + rng.random(m)) * np.maximum(p.totals, 1)
            if rng.random() < 0.3 and active.sum() > 1:
                durs[int(np.flatnonzero(active)[0])] = np.inf
            inc = p.next_batch(durs, active=active)
            assert inc.sum() == b
            assert np.all(inc >= 0)
            assert np.all(inc[~active] == 0)
        assert p.totals.sum() == b * a
