"""IDPA (Alg. 3.1) unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.idpa import (IDPAPartitioner, UDPAPartitioner,
                             effective_iterations, workload_balance_degree)


def drive(part, t):
    part.first_batch()
    while not part.done:
        part.next_batch(t * np.maximum(part.totals, 1))
    return part.totals


class TestEffectiveIterations:
    def test_eq6_formula(self):
        # K' = K + A/2 - 1 (paper Eq. 6, floored)
        assert effective_iterations(100, 10) == 104
        assert effective_iterations(10, 2) == 10    # 2 + (10 - 1) = 10 (floor)

    def test_bounds(self):
        with pytest.raises(ValueError):
            effective_iterations(10, 0)
        with pytest.raises(ValueError):
            effective_iterations(10, 11)

    def test_boundary_a_equals_k_rejected(self):
        """Paper requires A < K strictly; A == K must raise, A == K-1 is
        the largest legal allocation-batch count."""
        with pytest.raises(ValueError):
            effective_iterations(10, 10)
        assert effective_iterations(10, 9) == 10 + 9 // 2 - 1 + 1  # K+floor((A-1)/2)
        assert effective_iterations(2, 1) == 2


class TestIDPA:
    def test_first_batch_eq2(self):
        p = IDPAPartitioner(1000, 4, 2, frequencies=[1, 1, 1, 1])
        a = p.first_batch()
        assert a.sum() == 500 and np.all(a == 125)

    def test_first_batch_proportional(self):
        p = IDPAPartitioner(1000, 2, 2, frequencies=[1, 3])
        a = p.first_batch()
        assert a[0] == 125 and a[1] == 375           # floor + remainder

    def test_faster_nodes_get_more(self):
        t = np.array([2.0, 1.0, 0.5, 0.25])
        p = IDPAPartitioner(8000, 4, 4, frequencies=1 / t, mode="balanced")
        totals = drive(p, t)
        assert np.all(np.diff(totals) > 0)           # monotone in speed
        busy = t * totals
        assert workload_balance_degree(busy) > 0.95

    def test_balanced_beats_paper_mode_balance(self):
        t = np.array([2.0, 1.0, 0.5, 0.25, 0.125])
        res = {}
        for mode in ("paper", "balanced"):
            p = IDPAPartitioner(20000, 5, 5, frequencies=1 / t, mode=mode)
            totals = drive(p, t)
            res[mode] = workload_balance_degree(t * totals)
        assert res["balanced"] >= res["paper"]

    @settings(max_examples=50, deadline=None)
    @given(
        m=st.integers(2, 8),
        a=st.integers(1, 6),
        n_per=st.integers(50, 500),
        seed=st.integers(0, 100),
        mode=st.sampled_from(["paper", "balanced"]),
    )
    def test_invariants(self, m, a, n_per, seed, mode):
        """Every batch sums to floor(N/A); increments non-negative;
        totals == batch_size * A after driving."""
        rng = np.random.default_rng(seed)
        t = 0.25 + rng.random(m)
        N = n_per * m
        p = IDPAPartitioner(N, m, a, frequencies=1 / t, mode=mode)
        drive(p, t)
        b = N // a
        for alloc in p.history:
            assert alloc.sum() == b
            assert np.all(alloc >= 0)
        assert p.totals.sum() == b * a


class TestUDPA:
    def test_uniform(self):
        p = UDPAPartitioner(1200, 4, 3)
        p.allocate_all()
        assert np.all(p.totals == 300)


class TestBalanceDegree:
    def test_degenerate(self):
        assert workload_balance_degree([]) == 1.0
        assert workload_balance_degree([0, 0]) == 1.0

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.floats(0.1, 100), min_size=1, max_size=20))
    def test_range(self, loads):
        b = workload_balance_degree(loads)
        assert 0.0 < b <= 1.0
