"""Event-driven cluster simulator: paper-claim reproduction at metric level."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cluster_sim import ClusterSim, make_heterogeneous_speeds


def run(strategy, partitioning, m=6, N=6000, K=8, A=4, spread=0.8, seed=0,
        idpa_mode="paper"):
    t = make_heterogeneous_speeds(m, spread, seed)
    sim = ClusterSim(N, t, iterations=K, batches=A, strategy=strategy,
                     partitioning=partitioning, idpa_mode=idpa_mode)
    return sim.run()


class TestSyncWait:
    def test_agwu_has_zero_sync_wait(self):
        assert run("agwu", "idpa").sync_wait == 0.0

    def test_sgwu_waits_on_heterogeneous_cluster(self):
        assert run("sgwu", "udpa").sync_wait > 0.0

    def test_idpa_reduces_sgwu_wait(self):
        """Fig. 14: IDPA (balanced form) cuts the synchronisation wait."""
        w_udpa = run("sgwu", "udpa").sync_wait
        w_idpa = run("sgwu", "idpa", idpa_mode="balanced").sync_wait
        assert w_idpa < w_udpa


class TestCommunication:
    def test_eq11_both_strategies_equal(self):
        """AGWU and SGWU produce the same comm volume (Eq. 11)."""
        a = run("agwu", "idpa")
        s = run("sgwu", "idpa")
        assert a.comm_bytes == s.comm_bytes == a.expected_comm_bytes

    def test_comm_scales_linearly_with_nodes(self):
        """Fig. 15a: communication grows ~linearly in m (no data migration)."""
        c5 = run("agwu", "idpa", m=5).comm_bytes / 5
        c10 = run("agwu", "idpa", m=10).comm_bytes / 10
        assert c5 == pytest.approx(c10)


class TestWorkloadBalance:
    def test_idpa_beats_udpa_balance(self):
        """Fig. 15b (balanced IDPA form)."""
        b_idpa = run("agwu", "idpa", idpa_mode="balanced").balance_degree
        b_udpa = run("agwu", "udpa").balance_degree
        assert b_idpa > b_udpa

    def test_balance_in_unit_interval(self):
        for strat in ("agwu", "sgwu"):
            r = run(strat, "idpa")
            assert 0 < r.balance_degree <= 1.0


class TestMakespan:
    def test_agwu_idpa_fastest(self):
        """Fig. 14: AGWU+IDPA(balanced) <= SGWU+UDPA in virtual makespan."""
        fast = run("agwu", "idpa", idpa_mode="balanced").makespan
        slow = run("sgwu", "udpa").makespan
        assert fast < slow

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(2, 10), seed=st.integers(0, 50))
    def test_makespan_positive_and_allocation_complete(self, m, seed):
        r = run("agwu", "idpa", m=m, seed=seed)
        assert r.makespan > 0
        assert r.allocation.sum() == (6000 // 4) * 4


class TestIDPAFeedbackSignal:
    def test_agwu_feeds_charged_durations_not_fresh_rolls(self):
        """Regression: the incremental allocation must consume the
        durations the simulation actually charged — one noisy roll per
        scheduled work unit, ZERO extra rolls at allocation points."""
        m, K = 3, 4
        sim = ClusterSim(600, np.ones(m), iterations=K, batches=2,
                         strategy="agwu", partitioning="idpa", noise=0.5)
        calls = []
        orig = sim._duration

        def counting(node, nsamples):
            calls.append(node)
            return orig(node, nsamples)

        sim._duration = counting
        res = sim.run()
        assert res.makespan > 0
        assert len(calls) == m * K           # exactly one roll per work unit

    def test_agwu_allocation_tracks_observed_load(self):
        """A node the sim charges as slow must end up allocated fewer
        samples once IDPA re-partitions on the charged durations."""
        t = np.array([1.0, 1.0, 3.0])        # node 2 is 3x slower
        sim = ClusterSim(900, t, iterations=6, batches=3,
                         strategy="agwu", partitioning="idpa",
                         idpa_mode="balanced", noise=0.2, seed=2)
        res = sim.run()
        assert res.allocation[2] < res.allocation[0]
        assert res.allocation[2] < res.allocation[1]


class TestRealTraining:
    def test_weight_math_is_applied(self):
        """worker_train results actually land in the global weights."""
        import jax.numpy as jnp
        w0 = {"w": jnp.zeros((4,), jnp.float32)}

        def worker_train(j, w, idx, it):
            return {"w": w["w"] + 1.0}, 0.9

        t = np.ones(3)
        sim = ClusterSim(300, t, iterations=2, batches=2, strategy="agwu",
                         partitioning="idpa")
        res = sim.run(init_weights=w0, worker_train=worker_train)
        assert float(np.asarray(res.final_weights["w"]).sum()) > 0
