"""Regression tests for the fused (vmapped) outer layer.

The fused SGWU round — node-stacked params/opt-states, one jitted
vmap-over-nodes × scan-over-local-steps dispatch, donated merge — must be
numerically equivalent to the legacy sequential per-node loop it replaced,
and the AGWU bookkeeping helpers must match their pre-refactor behaviour.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bpt_trainer import BPTTrainer
from repro.core.gwu import agwu_gamma, broadcast_tree
from repro.core.param_server import ParameterServer
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.models.cnn import CNNConfig, cnn_loss, init_cnn


def _run_sgwu(m: int, fused: bool, rounds: int = 3):
    """One SGWU training run on a fixed seed; batches=1 freezes the IDPA
    allocation so both paths see identical data regardless of wall time."""
    cfg = CNNConfig(name="equiv", image_size=8, conv_layers=1, filters=4,
                    fc_layers=1, fc_neurons=32)
    xs, ys = image_dataset(64 * m * 2, size=8, seed=0)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=m, batches=1)
    tc = TrainConfig(outer_strategy="sgwu", outer_nodes=m,
                     optimizer="adamw", learning_rate=2e-3,
                     total_steps=100, warmup_steps=5, local_steps=2,
                     seed=0, fused_outer=fused)
    tr = BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}), params, ds, tc,
                    batch_size=32)
    return tr.train(rounds=rounds)


class TestFusedSequentialEquivalence:
    @pytest.mark.parametrize("m", [1, 4])
    def test_same_losses_and_weights(self, m):
        fused = _run_sgwu(m, fused=True)
        seq = _run_sgwu(m, fused=False)
        np.testing.assert_allclose(fused.losses, seq.losses,
                                   rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(fused.final_params),
                        jax.tree_util.tree_leaves(seq.final_params),
                        strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_same_comm_accounting(self):
        fused = _run_sgwu(4, fused=True)
        seq = _run_sgwu(4, fused=False)
        assert fused.comm_bytes == seq.comm_bytes


class TestStackedBatches:
    def test_matches_sequential_draw_order(self):
        """(m, local_steps, B, ...) stacking consumes the RNG exactly like
        the per-node loop, so fixed seeds stay comparable."""
        xs, ys = image_dataset(240, size=8, seed=1)
        m, h, bsz = 3, 2, 16
        ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=m, batches=1)
        stacked = ds.stacked_round_batches(
            bsz, h, np.random.default_rng(7))
        rng = np.random.default_rng(7)
        for j in range(m):
            for s in range(h):
                want = ds.node_batch(j, bsz, rng)
                np.testing.assert_array_equal(stacked["images"][j, s],
                                              want["images"])
                np.testing.assert_array_equal(stacked["labels"][j, s],
                                              want["labels"])


def _tree(val):
    return {"a": jnp.full((3, 2), val, jnp.float32),
            "b": jnp.full((4,), 2 * val, jnp.float32)}


class TestStackedParameterServer:
    def test_stacked_push_matches_list_push(self):
        locals_ = [_tree(1.0), _tree(3.0), _tree(5.0)]
        qs = [0.2, 0.3, 0.5]
        ps_list = ParameterServer(_tree(0.0), num_workers=3)
        for j in range(3):
            ps_list.pull(j)
        ps_list.push_sgwu(list(zip(range(3), locals_, qs, strict=True)))

        ps_stacked = ParameterServer(_tree(0.0), num_workers=3)
        ps_stacked.pull_all_stacked()
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *locals_)
        ps_stacked.push_sgwu_stacked(stacked, qs)

        for a, b in zip(jax.tree_util.tree_leaves(ps_list.global_weights),
                        jax.tree_util.tree_leaves(ps_stacked.global_weights),
                        strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)
        assert ps_list.comm_bytes == ps_stacked.comm_bytes
        assert ps_list.version == ps_stacked.version

    def test_pull_all_returns_replicas(self):
        ps = ParameterServer(_tree(2.0), num_workers=4)
        stacked, version = ps.pull_all_stacked()
        assert version == 0
        for leaf, ref in zip(jax.tree_util.tree_leaves(stacked),
                             jax.tree_util.tree_leaves(ps.global_weights),
                             strict=True):
            assert leaf.shape == (4,) + ref.shape
            np.testing.assert_allclose(np.asarray(leaf),
                                       np.broadcast_to(np.asarray(ref),
                                                       leaf.shape))
        assert ps.comm_bytes == 4 * ps.weight_bytes

    def test_rebroadcast_cache_survives_round_trip(self):
        """pull → push → pull must hand out the *merged* weights."""
        ps = ParameterServer(_tree(0.0), num_workers=2)
        ps.pull_all_stacked()
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), _tree(1.0), _tree(3.0))
        ps.push_sgwu_stacked(stacked, [0.5, 0.5])
        again, version = ps.pull_all_stacked()
        assert version == 1
        np.testing.assert_allclose(np.asarray(again["a"][0]), 2.0, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(again["a"][1]), 2.0, rtol=1e-6)


def _agwu_gamma_seed_impl(base_version, latest_version, outstanding_versions):
    """The pre-refactor implementation, verbatim: Eq. (9) evaluated through
    ``jnp.exp`` (a device round-trip per push)."""
    denom_versions = list(outstanding_versions) + [base_version]
    i_minus_1 = max(latest_version, 1)
    num = float(jnp.exp(base_version / i_minus_1))
    den = float(sum(jnp.exp(v / i_minus_1) for v in denom_versions))
    return num / den


_VERSION_GRID = [(k, latest, out)
                 for k in (0, 1, 2, 5, 9, 13, 20)
                 for latest in (1, 2, 3, 8, 15, 21)
                 for out in ([], [0], [1, 4], [2, 5, 9], [0, 7, 13, 20])
                 if k <= latest]


class TestAgwuGammaRegression:
    def test_matches_seed_impl_f64(self):
        """Pure-python agwu_gamma == the old jnp implementation to 1e-12.

        The old path's *math* is compared under x64 — its default-config
        output was additionally rounded through float32 by the device
        round-trip, which is the very noise (and cost) the rewrite
        removes; the f32 agreement is checked separately below.
        """
        from jax.experimental import enable_x64
        with enable_x64():
            for k, latest, out in _VERSION_GRID:
                old = _agwu_gamma_seed_impl(k, latest, out)
                new = agwu_gamma(k, latest, out)
                assert abs(old - new) < 1e-12, (k, latest, out)

    def test_matches_seed_impl_f32_tolerance(self):
        for k, latest, out in _VERSION_GRID:
            old = _agwu_gamma_seed_impl(k, latest, out)
            new = agwu_gamma(k, latest, out)
            assert abs(old - new) < 1e-6, (k, latest, out)


class TestBroadcastTree:
    def test_shapes_and_values(self):
        t = _tree(3.0)
        s = broadcast_tree(t, 5)
        assert s["a"].shape == (5, 3, 2)
        np.testing.assert_allclose(np.asarray(s["b"]),
                                   np.broadcast_to(np.asarray(t["b"]), (5, 4)))
