"""Outer-layer micro-benchmark: fused vmapped SGWU round vs the legacy
sequential per-node loop.

The sequential emulation dispatches m × local_steps jitted steps from the
host (plus a device sync per node), so its SGWU round cost grows linearly
in m from dispatch alone — the synchronization overhead BPT-CNN's outer
layer is meant to remove.  The fused path runs the whole nodes ×
local_steps grid as ONE vmap+scan dispatch against node-stacked pytrees.

With >= 8 devices the benchmark also records a 2-D hybrid-mesh row —
the planner-driven ``(nodes=4, model=2)`` SGWU round — into the same
CSV/JSON trajectory (data, not a gate: emulated host devices share one
silicon, so hybrid wall time only tracks dispatch overhead here).

Run:  python -m benchmarks.outer_loop [--report-only] [--json PATH]
Emits ``name,us_per_call,derived`` CSV rows (house format) on stdout —
pass/fail prose goes to stderr so the CSV stays machine-parseable — and
exits non-zero if the fused round is not at least 2x faster at m = 8
(the PR 1 floor — enforced nightly by the CI ``slow`` job AND on every
PR by the ``multidevice`` job, which gates the engine-layer indirection
against it).  ``--json``
additionally writes the measurements + verdict as one JSON document (the
``BENCH_outer.json`` workflow artifact that seeds the benchmark
trajectory).  ``--report-only`` skips the exit-code gate.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax

from benchmarks.common import emit
from repro.core.bpt_trainer import BPTTrainer
from repro.core.engine import engine_config
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.models.cnn import CNNConfig, cnn_loss, init_cnn

NODE_COUNTS = (4, 8, 16)
LOCAL_STEPS = 2
ROUNDS = 6
BATCH = 32
SPEEDUP_FLOOR = 2.0          # at m = 8 (the PR 1 acceptance floor)


def _make_trainer(m: int, engine: str, xs, ys, params, cfg,
                  mesh_name: str = "") -> BPTTrainer:
    """``engine`` is a repro.core.engine name: "sequential", "vmap" or
    "device" (pass ``mesh_name`` to place a named — possibly 2-D hybrid
    — mesh; the hybrid row hands the planner the model config)."""
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=m, batches=1)
    tc = TrainConfig(**engine_config(
        engine, outer_nodes=m, optimizer="adamw", learning_rate=2e-3,
        total_steps=1000, warmup_steps=10, local_steps=LOCAL_STEPS, seed=0,
        mesh_name=mesh_name))
    return BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}), params, ds, tc,
                      batch_size=BATCH, model_cfg=cfg)


def _time_rounds(trainer: BPTTrainer, rounds: int, repeats: int = 2) -> float:
    """Best-of-``repeats`` per-round time (min rejects scheduler noise)."""
    trainer.train(rounds=1)                    # warmup: compile both paths
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        trainer.train(rounds=rounds)
        best = min(best, (time.perf_counter() - t0) / rounds)
    return best


def run_all():
    """Returns (ok, results, hybrid): per-m timings, the m=8 gate
    verdict, and the 2-D hybrid-mesh row (None under 8 devices)."""
    cfg = CNNConfig(name="outer-bench", image_size=8, conv_layers=1,
                    filters=4, fc_layers=1, fc_neurons=32)
    xs, ys = image_dataset(2048, size=8, seed=0)
    params = init_cnn(jax.random.PRNGKey(0), cfg)

    ok = True
    results = {}
    for m in NODE_COUNTS:
        seq = _time_rounds(_make_trainer(m, "sequential", xs, ys, params,
                                         cfg), ROUNDS)
        fused = _time_rounds(_make_trainer(m, "vmap", xs, ys, params, cfg),
                             ROUNDS)
        speedup = seq / fused
        emit(f"sgwu_round_sequential_m{m}", seq * 1e6, "")
        emit(f"sgwu_round_fused_m{m}", fused * 1e6, f"speedup={speedup:.2f}x")
        results[m] = {"sequential_us": seq * 1e6, "fused_us": fused * 1e6,
                      "speedup": speedup}
        if m == 8 and speedup < SPEEDUP_FLOOR:
            ok = False

    # 2-D hybrid-mesh row: (nodes=4, model=2) planner-driven round on 8
    # devices (trajectory data, not a gate — emulated host devices share
    # the same silicon, so no speedup floor is meaningful here)
    hybrid = None
    if len(jax.devices()) >= 8:
        tr = _make_trainer(4, "device", xs, ys, params, cfg,
                           mesh_name="nodes4xmodel2")
        hyb = _time_rounds(tr, ROUNDS)
        rep = tr.last_plan
        family = getattr(tr.last_engine, "netplan", None)
        family = family.family if family is not None else ""
        emit("sgwu_round_hybrid_4x2", hyb * 1e6,
             f"backend={rep.backend};family={family}")
        hybrid = {"mesh": "nodes4xmodel2", "hybrid_us": hyb * 1e6,
                  "backend": rep.backend, "family": family}
    return ok, results, hybrid


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--report-only", action="store_true",
                    help="never fail the exit code (noisy shared runners)")
    ap.add_argument("--json", metavar="PATH", default="",
                    help="write measurements + verdict as JSON (the "
                    "BENCH_outer.json CI artifact)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    ok, results, hybrid = run_all()
    if args.json:
        doc = {
            "bench": "outer_loop",
            "local_steps": LOCAL_STEPS,
            "rounds": ROUNDS,
            "batch": BATCH,
            "floor": SPEEDUP_FLOOR,
            "gate_m": 8,
            "speedup_m8": results[8]["speedup"],
            "pass": ok,
            "nodes": {str(m): r for m, r in results.items()},
        }
        if hybrid is not None:
            doc["hybrid"] = hybrid
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
    if not ok:
        print(f"FAIL: fused SGWU round < {SPEEDUP_FLOOR}x faster than "
              "sequential at m=8", file=sys.stderr)
        if not args.report_only:
            sys.exit(1)
    else:
        print(f"OK: fused SGWU round >= {SPEEDUP_FLOOR}x faster than "
              "sequential at m=8", file=sys.stderr)


if __name__ == "__main__":
    main()
