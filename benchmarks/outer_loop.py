"""Outer-layer micro-benchmark: fused vmapped SGWU round vs the legacy
sequential per-node loop.

The sequential emulation dispatches m × local_steps jitted steps from the
host (plus a device sync per node), so its SGWU round cost grows linearly
in m from dispatch alone — the synchronization overhead BPT-CNN's outer
layer is meant to remove.  The fused path runs the whole nodes ×
local_steps grid as ONE vmap+scan dispatch against node-stacked pytrees.

Run:  python -m benchmarks.outer_loop [--report-only]
Emits ``name,us_per_call,derived`` CSV rows (house format) and a speedup
summary; exits non-zero if the fused round is not at least 2x faster at
m = 8 (the PR's acceptance gate).  ``--report-only`` skips the exit-code
gate — for shared CI runners whose wall-clock noise shouldn't redden a
scheduled job.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.bpt_trainer import BPTTrainer
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.models.cnn import CNNConfig, cnn_loss, init_cnn

NODE_COUNTS = (4, 8, 16)
LOCAL_STEPS = 2
ROUNDS = 6
BATCH = 32


def _make_trainer(m: int, fused: bool, xs, ys, params, cfg) -> BPTTrainer:
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=m, batches=1)
    tc = TrainConfig(outer_strategy="sgwu", outer_nodes=m,
                     optimizer="adamw", learning_rate=2e-3,
                     total_steps=1000, warmup_steps=10,
                     local_steps=LOCAL_STEPS, seed=0, fused_outer=fused)
    return BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}), params, ds, tc,
                      batch_size=BATCH)


def _time_rounds(trainer: BPTTrainer, rounds: int, repeats: int = 2) -> float:
    """Best-of-``repeats`` per-round time (min rejects scheduler noise)."""
    trainer.train(rounds=1)                    # warmup: compile both paths
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        trainer.train(rounds=rounds)
        best = min(best, (time.perf_counter() - t0) / rounds)
    return best


def run_all() -> bool:
    cfg = CNNConfig(name="outer-bench", image_size=8, conv_layers=1,
                    filters=4, fc_layers=1, fc_neurons=32)
    xs, ys = image_dataset(2048, size=8, seed=0)
    params = init_cnn(jax.random.PRNGKey(0), cfg)

    ok = True
    for m in NODE_COUNTS:
        seq = _time_rounds(_make_trainer(m, False, xs, ys, params, cfg),
                           ROUNDS)
        fused = _time_rounds(_make_trainer(m, True, xs, ys, params, cfg),
                             ROUNDS)
        speedup = seq / fused
        emit(f"sgwu_round_sequential_m{m}", seq * 1e6, "")
        emit(f"sgwu_round_fused_m{m}", fused * 1e6, f"speedup={speedup:.2f}x")
        if m == 8 and speedup < 2.0:
            ok = False
    return ok


def main() -> None:
    report_only = "--report-only" in sys.argv[1:]
    print("name,us_per_call,derived")
    ok = run_all()
    if not ok:
        print("FAIL: fused SGWU round < 2x faster than sequential at m=8",
              file=sys.stderr)
        if not report_only:
            sys.exit(1)
    else:
        print("OK: fused SGWU round >= 2x faster than sequential at m=8")


if __name__ == "__main__":
    main()
