"""One benchmark per paper table/figure (virtual time where the paper used a
30-node cluster; real JAX training where the paper measured accuracy).

Fig. 11  accuracy parity (sync vs SGWU vs AGWU, real CNN training)
Fig. 12  execution time vs data size / cluster scale (event-driven sim)
Tab. 1 / Fig. 13  iterations & time to fixed accuracy (real training)
Fig. 14  AGWU/SGWU x IDPA/UDPA strategy grid (sim + real)
Fig. 15  communication volume & workload balance vs cluster size (sim)
Fig. 10  inner-layer task scheduling (Alg. 4.2 scheduler)
"""
from __future__ import annotations


from repro.core.cluster_sim import ClusterSim, make_heterogeneous_speeds
from repro.core.dag import cnn_training_dag, priority_schedule

from .common import cnn_experiment, emit


def fig11_accuracy():
    """Accuracy parity: AGWU must match or beat the sync baseline."""
    accs = {}
    for strat in ("sync", "sgwu", "agwu"):
        rep, wall = cnn_experiment(strat, "idpa", rounds=8)
        acc = rep.accuracies[-1][1] if rep.accuracies else float("nan")
        accs[strat] = acc
        emit(f"fig11_accuracy_{strat}", rep.virtual_makespan * 1e6,
             f"final_acc={acc:.3f}")
    emit("fig11_agwu_vs_sync_delta", 0.0,
         f"delta={accs['agwu'] - accs['sync']:+.3f}")


def fig12_exec_time():
    """Virtual makespan vs data size and cluster scale (paper Fig. 12)."""
    for n in (100_000, 300_000, 700_000):
        sim = ClusterSim(n, make_heterogeneous_speeds(10, 0.6),
                         iterations=10, batches=4, strategy="agwu",
                         partitioning="idpa", idpa_mode="balanced")
        r = sim.run()
        emit(f"fig12a_datasize_{n}", r.makespan * 1e6,
             f"makespan={r.makespan:.1f}")
    for m in (5, 15, 25, 35):
        sim = ClusterSim(300_000, make_heterogeneous_speeds(m, 0.6),
                         iterations=10, batches=4, strategy="agwu",
                         partitioning="idpa", idpa_mode="balanced")
        r = sim.run()
        emit(f"fig12b_cluster_{m}", r.makespan * 1e6,
             f"makespan={r.makespan:.1f} speedup_vs_m5=see_csv")


def tab1_fixed_accuracy(target: float = 0.5):
    """Rounds needed to reach the target accuracy (paper Table 1)."""
    for strat in ("sync", "sgwu", "agwu"):
        rep, wall = cnn_experiment(strat, "idpa", rounds=10)
        hit = next((i + 1 for i, (t, a) in enumerate(rep.accuracies)
                    if a >= target), None)
        emit(f"tab1_rounds_to_{target}_{strat}",
             rep.virtual_makespan * 1e6,
             f"rounds={hit if hit else 'not_reached'}")


def fig14_strategies():
    """AGWU/SGWU x IDPA/UDPA grid — virtual makespan + real accuracy."""
    for strat in ("sgwu", "agwu"):
        for part in ("udpa", "idpa"):
            rep, wall = cnn_experiment(strat, part, rounds=5)
            acc = rep.accuracies[-1][1] if rep.accuracies else float("nan")
            emit(f"fig14_{strat}_{part}", rep.virtual_makespan * 1e6,
                 f"acc={acc:.3f};sync_wait={rep.sync_wait:.2f}")


def fig15_comm_balance():
    """Communication volume and workload balance vs cluster size."""
    for m in (5, 15, 25, 35):
        sim = ClusterSim(600_000, make_heterogeneous_speeds(m, 0.6),
                         iterations=10, batches=4, strategy="agwu",
                         partitioning="idpa", idpa_mode="balanced")
        r = sim.run()
        emit(f"fig15_m{m}", r.makespan * 1e6,
             f"comm_MB={r.comm_bytes/2**20:.3f};balance={r.balance_degree:.3f}")


def fig10_inner_scheduling():
    """Alg. 4.2 thread scheduling of the CNN task DAG."""
    dag = cnn_training_dag([
        {"kind": "conv", "hx": 32, "wx": 32, "hf": 3, "wf": 3, "depth": 3},
        {"kind": "pool", "hx": 32, "wx": 32, "k": 2},
        {"kind": "conv", "hx": 16, "wx": 16, "hf": 3, "wf": 3, "depth": 8},
        {"kind": "fc", "in": 2048, "out": 500},
    ], tile=4)
    serial = priority_schedule(dag, 1).makespan
    for threads in (2, 4, 8, 16):
        r = priority_schedule(dag, threads)
        emit(f"fig10_threads_{threads}", r.makespan,
             f"speedup={serial / r.makespan:.2f};balance="
             f"{r.balance_degree:.3f};waiting={r.waiting_time:.1f}")


def run_all():
    fig11_accuracy()
    fig12_exec_time()
    tab1_fixed_accuracy()
    fig14_strategies()
    fig15_comm_balance()
    fig10_inner_scheduling()
