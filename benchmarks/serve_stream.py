"""Request-stream serving benchmark: continuous vs static batching.

Replays ONE Poisson-arrival request stream (exponential gaps, heavy-
tailed generation lengths — arrivals straddle batch boundaries) through
both serve engines and measures throughput and latency percentiles.
Static batching pays two costs continuous batching removes: a group
only starts when its last member arrives, and the whole group drains at
the max generation length of its members.

Run:  python -m benchmarks.serve_stream [--report-only] [--json PATH]
Emits ``name,us_per_call,derived`` CSV rows (house format) on stdout —
prose goes to stderr — and exits non-zero unless continuous batching
reaches ``FLOOR``x static throughput (the nightly CI gate).  ``--json``
writes the measurements + verdict as one JSON document (the
``BENCH_serve.json`` workflow artifact).  ``--timing model`` swaps the
measured wall clock for the deterministic cost model (hermetic runs on
noisy shared runners).
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from benchmarks.common import emit, section
from repro.core.types import ModelConfig
from repro.models import lm
from repro.serving import ServeConfig, make_serve_engine, poisson_requests

N_REQUESTS = 32
RATE_RPS = 1000.0           # mean 1 ms gap: load-bound, arrivals straddle groups
SLOTS = 4
MAX_SEQ = 96
FLOOR = 1.5                  # continuous >= FLOOR x static throughput


def _bench_cfg() -> ModelConfig:
    return ModelConfig(name="serve-bench", arch_type="dense", num_layers=2,
                       d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                       d_ff=128, vocab_size=256)


def _run_stream(params, cfg, batching: str, timing: str, reqs):
    """One replay; returns the metrics dict for this engine."""
    eng = make_serve_engine(params, cfg, ServeConfig(
        slots=SLOTS, max_seq=MAX_SEQ, batching=batching, timing=timing))
    if timing == "measured":
        # warmup replay on the SAME engine: compiles every prompt shape
        # off the clock (a full run ends with all slots evicted, so the
        # measured replay starts from a clean cache)
        for _ev in eng.run(reqs):
            pass
    tok_ms, ttft, lat = [], [], []
    tokens = 0
    makespan = 0.0
    for ev in eng.run(reqs):
        if ev.kind == "token":
            tok_ms.append(ev.decode_ms)
        elif ev.kind == "prefill":
            ttft.append(ev.ttft_ms)
        elif ev.kind == "complete":
            lat.append(ev.latency_ms)
            tokens += len(ev.tokens)
            makespan = ev.t_ms
    assert len(lat) == len(reqs), (batching, len(lat))
    return {
        "batching": batching,
        "tokens": tokens,
        "makespan_ms": makespan,
        "throughput_tok_s": tokens / makespan * 1e3,
        "token_ms_p50": float(np.percentile(tok_ms, 50)),
        "token_ms_p99": float(np.percentile(tok_ms, 99)),
        "ttft_ms_p50": float(np.percentile(ttft, 50)),
        "ttft_ms_p99": float(np.percentile(ttft, 99)),
        "latency_ms_p50": float(np.percentile(lat, 50)),
        "latency_ms_p99": float(np.percentile(lat, 99)),
        "prefill_traces": eng.prefill_traces,
        "decode_traces": eng.decode_traces,
    }


def run_all(timing: str):
    cfg = _bench_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    reqs = poisson_requests(N_REQUESTS, RATE_RPS, seed=7,
                            vocab_size=cfg.vocab_size)
    section(f"serve stream: {N_REQUESTS} requests @ {RATE_RPS}/s, "
            f"slots={SLOTS}, timing={timing}")
    results = {}
    for batching in ("static", "continuous"):
        r = _run_stream(params, cfg, batching, timing, reqs)
        results[batching] = r
        emit(f"serve_{batching}_token_step", r["token_ms_p50"] * 1e3,
             f"tok_s={r['throughput_tok_s']:.1f};"
             f"p99_ms={r['token_ms_p99']:.2f}")
        emit(f"serve_{batching}_request_latency",
             r["latency_ms_p50"] * 1e3,
             f"p99_ms={r['latency_ms_p99']:.1f};"
             f"ttft_p50_ms={r['ttft_ms_p50']:.1f}")
    ratio = (results["continuous"]["throughput_tok_s"]
             / results["static"]["throughput_tok_s"])
    emit("serve_continuous_vs_static", ratio * 1e6,
         f"throughput_ratio={ratio:.2f}x;floor={FLOOR}x")
    return ratio >= FLOOR, ratio, results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--report-only", action="store_true",
                    help="never fail the exit code (noisy shared runners)")
    ap.add_argument("--json", metavar="PATH", default="",
                    help="write measurements + verdict as JSON (the "
                    "BENCH_serve.json CI artifact)")
    ap.add_argument("--timing", default="measured",
                    choices=["measured", "model"],
                    help="virtual-clock source (model = deterministic)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    ok, ratio, results = run_all(args.timing)
    if args.json:
        doc = {
            "bench": "serve_stream",
            "requests": N_REQUESTS,
            "rate_rps": RATE_RPS,
            "slots": SLOTS,
            "max_seq": MAX_SEQ,
            "timing": args.timing,
            "floor": FLOOR,
            "throughput_ratio": ratio,
            "pass": ok,
            "engines": results,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
    if not ok:
        print(f"FAIL: continuous batching {ratio:.2f}x static throughput "
              f"< {FLOOR}x floor", file=sys.stderr)
        if not args.report_only:
            sys.exit(1)
    else:
        print(f"OK: continuous batching {ratio:.2f}x static throughput "
              f"(floor {FLOOR}x)", file=sys.stderr)


if __name__ == "__main__":
    main()
