"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json).

Emits one CSV row per (arch x shape x mesh): the three roofline terms in
ms, the dominant bottleneck, the useful-FLOP fraction, and per-device HBM.
The EXPERIMENTS.md §Roofline table is generated from this output
(``python -m benchmarks.roofline_report --markdown``).
"""
from __future__ import annotations

import glob
import json
import os
import sys

from .common import emit

DRYRUN_DIR = "experiments/dryrun"


def load_results(mesh_filter: str = "", tag: str = ""):
    rows = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        base = os.path.basename(fn)[:-5]
        parts = base.split("__")
        file_tag = parts[3] if len(parts) > 3 else ""
        if tag != file_tag:
            continue
        data = json.load(open(fn))
        if mesh_filter and data["mesh"] != mesh_filter:
            continue
        if "roofline" not in data:
            continue
        rows.append(data)
    return rows


def run_all(mesh: str = "pod", tag: str = ""):
    rows = load_results(mesh_filter=mesh, tag=tag)
    if not rows:
        emit(f"roofline_{mesh}", 0.0, "no_dryrun_artifacts_yet")
        return
    for d in rows:
        r = d["roofline"]
        bound_ms = max(r["compute_ms"], r["memory_ms"], r["collective_ms"])
        emit(f"roofline_{d['arch']}_{d['shape']}_{d['mesh']}",
             bound_ms * 1e3,
             f"bottleneck={r['bottleneck']};compute_ms={r['compute_ms']};"
             f"memory_ms={r['memory_ms']};coll_ms={r['collective_ms']};"
             f"useful={r['useful_frac']};hbm_GB={r['hbm_per_dev_GB']}")


def markdown_table(mesh: str = "pod", tag: str = "") -> str:
    rows = load_results(mesh_filter=mesh, tag=tag)
    hdr = ("| arch | shape | chips | compute ms | memory ms | mem(flash) ms "
           "| coll ms | bottleneck | useful frac | HBM/dev GB | compile s |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for d in sorted(rows, key=lambda d: (d["arch"], d["shape"])):
        r = d["roofline"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['chips']} "
            f"| {r['compute_ms']} | {r['memory_ms']} "
            f"| {r.get('memory_flash_ms', '-')} | {r['collective_ms']} "
            f"| **{r['bottleneck']}** | {r['useful_frac']} "
            f"| {r['hbm_per_dev_GB']} | {d['compile_s']} |")
    return "\n".join(lines)


if __name__ == "__main__":
    if "--markdown" in sys.argv:
        mesh = sys.argv[sys.argv.index("--mesh") + 1] \
            if "--mesh" in sys.argv else "pod"
        tag = sys.argv[sys.argv.index("--tag") + 1] \
            if "--tag" in sys.argv else ""
        print(markdown_table(mesh, tag))
    else:
        run_all()
