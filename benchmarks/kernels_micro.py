"""Kernel micro-benchmarks (CPU wall time of the jnp reference path +
Pallas interpret-mode correctness deltas).

Real Pallas timings need a TPU; here ``us_per_call`` is the jitted jnp ref
on CPU (a lower bound sanity signal) and ``derived`` carries the max
abs error of the Pallas kernel vs the oracle — the correctness half of the
kernel story that CAN be validated in this container.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.conv2d import conv2d_pallas
from repro.kernels.dense import dense_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.pool2d import max_pool2d_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas

from .common import emit, time_call


def bench_conv2d():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (8, 32, 32, 16))
    w = jax.random.normal(k2, (3, 3, 16, 32))
    us = time_call(jax.jit(lambda a, b: ref.conv2d_ref(a, b)), x, w)
    err = float(jnp.abs(conv2d_pallas(x[:1], w) -
                        ref.conv2d_ref(x[:1], w)).max())
    emit("kernel_conv2d_32x32x16x32", us, f"pallas_max_err={err:.2e}")


def _lax_conv(x, w, b):
    out = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(out + b)


def bench_conv2d_fwd_bwd(gate_atol: float = 1e-4):
    """Forward+backward conv benchmark, GATED against the lax.conv oracle.

    ``us_per_call`` times the jitted lax.conv value_and_grad on CPU (the
    achievable-lower-bound signal, like the other benches); ``derived``
    carries the Pallas custom_vjp max |err| for out/dx/dw/db vs that
    oracle.  Any error above ``gate_atol`` raises — the benchmark doubles
    as the fwd+bwd correctness gate runnable outside pytest.
    """
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (4, 16, 16, 8))
    w = jax.random.normal(k2, (3, 3, 8, 16))
    b = jax.random.normal(k3, (16,))

    def loss_lax(x_, w_, b_):
        return jnp.sum(_lax_conv(x_, w_, b_) ** 2)

    def loss_pallas(x_, w_, b_):
        out = conv2d_pallas(x_, w_, b_, activation="relu")
        return jnp.sum(out ** 2)

    us = time_call(jax.jit(jax.value_and_grad(loss_lax, argnums=(0, 1, 2))),
                   x, w, b)
    out_err = float(jnp.abs(conv2d_pallas(x, w, b, activation="relu") -
                            _lax_conv(x, w, b)).max())
    got = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(loss_lax, argnums=(0, 1, 2))(x, w, b)
    errs = {"out": out_err}
    for name, g, r in zip(("dx", "dw", "db"), got, want, strict=True):
        errs[name] = float(jnp.abs(g - r).max())
    scale = float(max(jnp.abs(r).max() for r in want))
    derived = ",".join(f"{k}_err={v:.2e}" for k, v in errs.items())
    emit("kernel_conv2d_fwdbwd_16x16x8x16", us, derived)
    worst = max(errs.values())
    if worst > gate_atol * max(scale, 1.0):
        raise RuntimeError(
            f"pallas conv fwd+bwd off the lax.conv oracle: {derived} "
            f"(gate {gate_atol:.0e} x scale {scale:.1f})")


def bench_pool2d(gate_atol: float = 1e-4):
    """Forward+backward pooling benchmark, GATED against the jnp oracle.

    ``us_per_call`` times the jitted ref value_and_grad on CPU;
    ``derived`` carries the Pallas custom_vjp max |err| for out/dx vs that
    oracle (ties included — the input is relu'd so windows tie often).
    Any error above ``gate_atol`` raises.
    """
    key = jax.random.PRNGKey(4)
    k1, k2 = jax.random.split(key)
    x = jax.nn.relu(jax.random.normal(k1, (8, 32, 32, 16)))
    cot = jax.random.normal(k2, (8, 16, 16, 16))

    def loss_ref(x_):
        return jnp.sum(ref.max_pool2d_ref(x_) * cot)

    def loss_pallas(x_):
        return jnp.sum(max_pool2d_pallas(x_) * cot)

    us = time_call(jax.jit(jax.value_and_grad(loss_ref)), x)
    out_err = float(jnp.abs(max_pool2d_pallas(x) -
                            ref.max_pool2d_ref(x)).max())
    dx_err = float(jnp.abs(jax.grad(loss_pallas)(x) -
                           jax.grad(loss_ref)(x)).max())
    derived = f"out_err={out_err:.2e},dx_err={dx_err:.2e}"
    emit("kernel_pool2d_fwdbwd_32x32x16", us, derived)
    if max(out_err, dx_err) > gate_atol:
        raise RuntimeError(
            f"pallas max_pool2d fwd+bwd off the jnp oracle: {derived} "
            f"(gate {gate_atol:.0e})")


def bench_dense(gate_atol: float = 1e-4):
    """Forward+backward fused-dense benchmark, GATED against the jnp oracle.

    ``us_per_call`` times the jitted jnp value_and_grad on CPU;
    ``derived`` carries the Pallas custom_vjp max |err| for out/dx/dw/db
    at the Alg. 4.2-style block (64 over 512 neurons).  Any error above
    ``gate_atol * scale`` raises — the G_FC correctness gate runnable
    outside pytest.
    """
    key = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (32, 256))
    w = jax.random.normal(k2, (256, 512))
    b = jax.random.normal(k3, (512,))

    def loss_ref(x_, w_, b_):
        return jnp.sum(ref.dense_ref(x_, w_, b_, activation="relu") ** 2)

    def loss_pallas(x_, w_, b_):
        return jnp.sum(dense_pallas(x_, w_, b_, activation="relu",
                                    block=64) ** 2)

    us = time_call(jax.jit(jax.value_and_grad(loss_ref, argnums=(0, 1, 2))),
                   x, w, b)
    out_err = float(jnp.abs(
        dense_pallas(x, w, b, activation="relu", block=64) -
        ref.dense_ref(x, w, b, activation="relu")).max())
    got = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    errs = {"out": out_err}
    for name, g, r in zip(("dx", "dw", "db"), got, want, strict=True):
        errs[name] = float(jnp.abs(g - r).max())
    scale = float(max(jnp.abs(r).max() for r in want))
    derived = ",".join(f"{k}_err={v:.2e}" for k, v in errs.items())
    emit("kernel_dense_fwdbwd_32x256x512", us, derived)
    worst = max(errs.values())
    if worst > gate_atol * max(scale, 1.0):
        raise RuntimeError(
            f"pallas dense fwd+bwd off the jnp oracle: {derived} "
            f"(gate {gate_atol:.0e} x scale {scale:.1f})")


def bench_flash():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 8, 512, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 2, 512, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 2, 512, 64), jnp.bfloat16)
    naive = jax.jit(lambda q_, k_, v_: ref.attention_ref(
        q_.transpose(0, 2, 1, 3), k_.transpose(0, 2, 1, 3),
        v_.transpose(0, 2, 1, 3), causal=True))
    us = time_call(naive, q, k, v)
    got = flash_attention_pallas(q[:1, :, :128], k[:1, :, :128],
                                 v[:1, :, :128], causal=True)
    want = ref.attention_ref(
        q[:1, :, :128].transpose(0, 2, 1, 3),
        k[:1, :, :128].transpose(0, 2, 1, 3),
        v[:1, :, :128].transpose(0, 2, 1, 3),
        causal=True).transpose(0, 2, 1, 3)
    err = float(jnp.abs(got.astype(jnp.float32) -
                        want.astype(jnp.float32)).max())
    emit("kernel_flash_gqa_512", us, f"pallas_max_err={err:.2e}")


def bench_rmsnorm():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (4096, 1024))
    s = jnp.ones((1024,))
    us = time_call(jax.jit(lambda a, b: ref.rmsnorm_ref(a, b)), x, s)
    err = float(jnp.abs(rmsnorm_pallas(x[:256], s) -
                        ref.rmsnorm_ref(x[:256], s)).max())
    emit("kernel_rmsnorm_4096x1024", us, f"pallas_max_err={err:.2e}")


def run_all():
    bench_conv2d()
    bench_conv2d_fwd_bwd()
    bench_pool2d()
    bench_dense()
    bench_flash()
    bench_rmsnorm()


if __name__ == "__main__":
    # the correctness-gated micro-benchmarks double as a CI gate:
    # any kernel off its oracle raises and fails the job
    run_all()
