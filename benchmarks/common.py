"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bpt_trainer import BPTTrainer
from repro.core.types import TrainConfig
from repro.data.pipeline import IDPADataset
from repro.data.synthetic import image_dataset
from repro.launch.runtime import maybe_enable_compilation_cache
from repro.models.cnn import CNNConfig, cnn_accuracy, cnn_loss, init_cnn
from repro.sanitize import compile_budget, install_compile_listener

# persistent XLA cache, ON by default: repeat benchmark runs skip
# compiles (REPRO_COMPILATION_CACHE=off opts out; the measured regions
# all warm up first, so timings are unaffected either way)
maybe_enable_compilation_cache()
# compile-event counter: time_call() asserts its measured repeats hit
# the dispatch cache — a benchmark that recompiles mid-measurement is
# timing XLA, not the kernel, and must fail loudly
install_compile_listener()

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    print(line)


def section(title: str):
    """Human-facing section banner.  Goes to STDERR on purpose: stdout is
    the machine-parseable ``name,us_per_call,derived`` CSV stream the CI
    benchmark gate consumes."""
    print(f"== {title} ==", file=sys.stderr)


def time_call(fn, *args, repeats=3):
    fn(*args)                                  # warmup/compile
    with compile_budget(0, label="time_call measured region"):
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def cnn_experiment(strategy: str, partitioning: str, *, nodes=3, rounds=6,
                   local_steps=3, n_train=1200, n_eval=300, seed=0,
                   idpa_mode="balanced", lr=2e-3, image_size=16):
    """One BPT-CNN training run; returns (TrainReport, wall_seconds)."""
    cfg = CNNConfig(name="bench", image_size=image_size, conv_layers=2,
                    filters=8, fc_layers=2, fc_neurons=64)
    xs, ys = image_dataset(n_train, size=image_size, seed=seed)
    xe, ye = image_dataset(n_eval, size=image_size, seed=seed + 77)
    params = init_cnn(jax.random.PRNGKey(seed), cfg)
    eval_batch = {"images": jnp.asarray(xe), "labels": jnp.asarray(ye)}
    eval_fn = jax.jit(lambda p: cnn_accuracy(p, eval_batch, cfg))
    speeds = 1.0 + 0.6 * np.arange(nodes) / max(nodes - 1, 1)
    ds = IDPADataset({"images": xs, "labels": ys}, num_nodes=nodes,
                     batches=3, frequencies=1.0 / speeds,
                     partitioning=partitioning, idpa_mode=idpa_mode)
    # fair comparison: the single-node sync baseline runs the same TOTAL
    # optimizer steps per round as the m parallel nodes combined
    eff_local = local_steps * (nodes if strategy == "sync" else 1)
    tc = TrainConfig(outer_strategy=strategy, partitioning=partitioning,
                     outer_nodes=nodes, optimizer="adamw",
                     learning_rate=lr, warmup_steps=10,
                     total_steps=rounds * local_steps * nodes,
                     local_steps=eff_local, seed=seed)
    tr = BPTTrainer(lambda p, b: (cnn_loss(p, b, cfg), {}), params, ds, tc,
                    batch_size=64, eval_fn=eval_fn, speed_factors=speeds)
    t0 = time.perf_counter()
    rep = tr.train(rounds=rounds)
    return rep, time.perf_counter() - t0
