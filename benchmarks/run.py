"""Benchmark harness: one function per paper table/figure.

Stdout carries ONLY the ``name,us_per_call,derived`` CSV stream (the CI
benchmark gate parses it); section banners and any other prose go to
stderr via ``common.section``.  Sections:
  * paper figures (Fig. 10-15, Table 1) — BPT-CNN reproduction metrics
  * kernel micro-benchmarks — jnp ref timing + Pallas correctness
  * roofline report — read from experiments/dryrun artifacts

``--json PATH`` additionally writes every emitted row as a JSON list of
``{name, us_per_call, derived}`` objects (workflow-artifact format).
"""
import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default="",
                    help="also write the emitted rows as JSON")
    args = ap.parse_args()

    from . import kernels_micro, paper_figures, roofline_report
    from .common import ROWS, section
    print("name,us_per_call,derived")
    section("paper figures (Fig. 10-15, Table 1)")
    paper_figures.run_all()
    section("kernel micro-benchmarks")
    kernels_micro.run_all()
    section("roofline report (pod)")
    roofline_report.run_all(mesh="pod")
    section("roofline report (multipod)")
    roofline_report.run_all(mesh="multipod")

    if args.json:
        rows = []
        for line in ROWS:
            name, us, derived = line.split(",", 2)
            rows.append({"name": name, "us_per_call": float(us),
                         "derived": derived})
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    main()
