"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sections:
  * paper figures (Fig. 10-15, Table 1) — BPT-CNN reproduction metrics
  * kernel micro-benchmarks — jnp ref timing + Pallas correctness
  * roofline report — read from experiments/dryrun artifacts
"""
import sys


def main() -> None:
    from . import kernels_micro, paper_figures, roofline_report
    print("name,us_per_call,derived")
    paper_figures.run_all()
    kernels_micro.run_all()
    roofline_report.run_all(mesh="pod")
    roofline_report.run_all(mesh="multipod")


if __name__ == "__main__":
    main()
